//! Table generators (paper §VI): each function returns the rows the
//! paper prints, computed on the simulated stack.

use crate::bench_util::{pct, Table};
use crate::compress::{baseline, codec, qtable::qtable};
use crate::config::{models, AccelConfig, Network};
use crate::data::{natural_image, Smoothness};
use crate::harness::profiles::{self, overall_ratio, to_sim_profiles};
use crate::sim::energy::{
    normalize_efficiency, AreaBreakdown, EnergyBreakdown,
};
use crate::sim::Accelerator;

/// Table I — hardware specifications.
pub fn table1(cfg: &AccelConfig) -> Table {
    let area = AreaBreakdown::compute(cfg);
    let mut t = Table::new(&["Specification", "Value"]);
    let kb = |b: usize| format!("{} KB", b / 1024);
    let rows: Vec<(&str, String)> = vec![
        ("Technology", format!("{} nm (modeled)", cfg.tech_nm)),
        ("Clock Rate", format!("{} MHz", cfg.clock_hz / 1e6)),
        (
            "Gate Count",
            format!("{} K", area.total_gates() / 1000),
        ),
        (
            "Core Area",
            format!("{:.2} mm^2 (paper: 1.65x1.3)", area.core_mm2()),
        ),
        ("Number of PEs", cfg.total_macs().to_string()),
        ("On-chip SRAM", kb(cfg.total_sram())),
        ("Index Buffer", kb(cfg.index_buffer)),
        (
            "Feature Map Buffer",
            format!(
                "{}~{}",
                kb(cfg.fmap_range().0),
                kb(cfg.fmap_range().1)
            ),
        ),
        (
            "Scratch Pad",
            format!(
                "{}~{}",
                kb(cfg.scratch_range().0),
                kb(cfg.scratch_range().1)
            ),
        ),
        ("Supply Voltage", format!("{} V", cfg.voltage)),
        (
            "Peak Throughput",
            format!("{:.0} GOPS", cfg.peak_gops()),
        ),
        (
            "Arithmetic Precision",
            format!("{}-bit fixed-point", cfg.precision_bits),
        ),
        (
            "CCMs in DCT / IDCT",
            format!("{} / {}", cfg.dct_ccms, cfg.idct_ccms),
        ),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t
}

/// One network's Table II row.
#[derive(Debug, Clone)]
pub struct MemAccessRow {
    pub network: String,
    /// DRAM feature-map traffic saved per inference (MB).
    pub data_reduction_mb: f64,
    /// DMA time saved per inference (ms).
    pub time_reduction_ms: f64,
    /// DCT/IDCT module power overhead (mW).
    pub power_overhead_mw: f64,
    /// DRAM power saved (mW).
    pub power_reduction_mw: f64,
}

/// Table II — external memory access saved by compression.
pub fn table2(cfg: &AccelConfig, seed: u64) -> Vec<MemAccessRow> {
    let accel = Accelerator::new(cfg.clone());
    models::paper_benchmarks()
        .into_iter()
        .map(|net| {
            let net = net.clone().with_paper_schedule();
            let prof = profiles::profile_network(&net, seed);
            let comp = accel.run(&net, &to_sim_profiles(&prof));
            let raw = accel.run_flat(&net, None);
            let saved_bytes = raw
                .dram_fmap_bytes()
                .saturating_sub(comp.dram_fmap_bytes());
            let saved_mb = saved_bytes as f64 / 1e6;
            let time_ms =
                saved_bytes as f64 / cfg.dma_bytes_per_s * 1e3;
            // DCT/IDCT power overhead over the compressed run
            let secs = comp.runtime_secs();
            let dct_w = (comp.energy.dct_j + comp.energy.idct_j)
                / secs.max(1e-12);
            // DRAM power saved = saved energy / runtime
            let saved_j =
                saved_bytes as f64 * 8.0 * cfg.dram_pj_per_bit * 1e-12;
            let dram_w = saved_j / secs.max(1e-12);
            MemAccessRow {
                network: net.name.clone(),
                data_reduction_mb: saved_mb,
                time_reduction_ms: time_ms,
                power_overhead_mw: dct_w * 1e3,
                power_reduction_mw: dram_w * 1e3,
            }
        })
        .collect()
}

pub fn table2_table(rows: &[MemAccessRow]) -> Table {
    let mut t = Table::new(&[
        "Network",
        "Data Reduction (MB/fig)",
        "Time Reduction (ms/fig)",
        "Power Overhead (mW)",
        "Power Reduction (mW)",
    ]);
    for r in rows {
        t.row(&[
            r.network.clone(),
            format!("{:.2}", r.data_reduction_mb),
            format!("{:.2}", r.time_reduction_ms),
            format!("{:.1}", r.power_overhead_mw),
            format!("{:.1}", r.power_reduction_mw),
        ]);
    }
    t
}

/// Table III — layer-by-layer compression ratios (first 10 fusion
/// layers) + overall, for the five benchmarks.
pub struct CompressionTable {
    pub networks: Vec<String>,
    /// per network: first-10 ratios
    pub first10: Vec<Vec<f64>>,
    pub overall: Vec<f64>,
    /// Per network, the full measured layer profiles the ratios were
    /// derived from — exposed so companions (the wire-drift table)
    /// don't recompress what this pass already profiled.
    pub profiles: Vec<Vec<Option<profiles::LayerProfile>>>,
}

pub fn table3(seed: u64) -> CompressionTable {
    let nets = models::paper_benchmarks();
    let mut networks = Vec::new();
    let mut first10 = Vec::new();
    let mut overall = Vec::new();
    let mut per_net_profiles = Vec::new();
    for net in nets {
        let net = net.with_paper_schedule();
        let prof = profiles::profile_network(&net, seed);
        let f10: Vec<f64> = prof
            .iter()
            .take(10)
            .flatten()
            .map(|p| p.ratio)
            .collect();
        overall.push(overall_ratio(&prof));
        networks.push(net.name.clone());
        first10.push(f10);
        per_net_profiles.push(prof);
    }
    CompressionTable {
        networks,
        first10,
        overall,
        profiles: per_net_profiles,
    }
}

pub fn table3_table(c: &CompressionTable) -> Table {
    let mut headers = vec!["Fusion Layer".to_string()];
    headers.extend(c.networks.iter().cloned());
    let hdr_refs: Vec<&str> =
        headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for i in 0..10 {
        let mut row = vec![format!("Fusion {}", i + 1)];
        for f in &c.first10 {
            row.push(
                f.get(i).map(|r| pct(*r)).unwrap_or("-".into()),
            );
        }
        t.row(&row);
    }
    let mut row = vec!["Overall".to_string()];
    for o in &c.overall {
        row.push(pct(*o));
    }
    t.row(&row);
    t
}

/// Table IV — comparison with the DAC'20 STC-like baseline.
pub struct StcRow {
    pub network: String,
    pub ours: f64,
    pub stc: f64,
}

pub fn table4(seed: u64) -> Vec<StcRow> {
    // Evaluate both codecs on the same depth-representative
    // activations of each network's first-10 layers.
    models::paper_benchmarks()
        .into_iter()
        .map(|net| {
            let net = net.with_paper_schedule();
            let prof = profiles::profile_network(&net, seed);
            let ours = overall_ratio(&prof);
            // STC on the same sampled maps
            let mut comp = 0f64;
            let mut raw = 0f64;
            for (i, l) in net.layers.iter().enumerate().take(10) {
                let (c, h, w) = l.out_dims();
                let fmap = natural_image(
                    seed ^ (i as u64) << 8,
                    c.min(8),
                    h,
                    w,
                    Smoothness::for_layer(i),
                    l.act.sparsifying(),
                );
                let (bits, _) = baseline::stc_compress(&fmap, 0.01);
                comp += bits as f64 / 8.0 / (c.min(8) as f64)
                    * (c as f64);
                raw += l.out_fmap_bytes() as f64;
            }
            StcRow {
                network: net.name.clone(),
                ours,
                stc: comp / raw,
            }
        })
        .collect()
}

/// One comparator row of Table V (quoted from the paper for the other
/// works; computed for ours).
#[derive(Debug, Clone)]
pub struct AccelRow {
    pub name: &'static str,
    pub tech_nm: f64,
    pub gops: f64,
    pub power_mw: f64,
    pub tops_per_w: f64,
    pub norm_tops_per_w: f64,
    pub fps_vgg: f64,
    pub compression: &'static str,
}

/// Table V — our column measured on the simulator, comparators quoted.
pub fn table5(cfg: &AccelConfig, seed: u64) -> Vec<AccelRow> {
    let accel = Accelerator::new(cfg.clone());
    let net = models::vgg16_bn().with_paper_schedule();
    let prof = profiles::profile_network(&net, seed);
    let rep = accel.run(&net, &to_sim_profiles(&prof));
    let ours_eff = rep.tops_per_w();
    let quoted = vec![
        AccelRow {
            name: "TCASI'18 [14]",
            tech_nm: 65.0,
            gops: 152.0,
            power_mw: 350.0,
            tops_per_w: 0.434,
            norm_tops_per_w: normalize_efficiency(0.434, 65.0),
            fps_vgg: 4.95,
            compression: "N/A",
        },
        AccelRow {
            name: "JSSC'17 [23] (Eyeriss)",
            tech_nm: 65.0,
            gops: 84.0,
            power_mw: 236.0,
            tops_per_w: 0.357,
            norm_tops_per_w: normalize_efficiency(0.357, 65.0),
            fps_vgg: 0.7,
            compression: "Run Length",
        },
        AccelRow {
            name: "JSSC'20 [28] (STICKER)",
            tech_nm: 65.0,
            gops: 5638.0,
            power_mw: 248.4,
            tops_per_w: 62.1,
            norm_tops_per_w: normalize_efficiency(62.1, 65.0),
            fps_vgg: f64::NAN, // AlexNet benchmarked in the paper
            compression: "CSR/COO",
        },
        AccelRow {
            name: "ISSCC'17 [24] (Envision)",
            tech_nm: 28.0,
            gops: 1632.0,
            power_mw: 26.0,
            tops_per_w: 10.0,
            norm_tops_per_w: 10.0,
            fps_vgg: 1.67,
            compression: "N/A",
        },
        AccelRow {
            name: "DATE'17 [30] (Chain-NN)",
            tech_nm: 28.0,
            gops: 806.0,
            power_mw: 567.5,
            tops_per_w: 1.42,
            norm_tops_per_w: 1.42,
            fps_vgg: f64::NAN, // AlexNet
            compression: "N/A",
        },
    ];
    let mut rows = quoted;
    rows.push(AccelRow {
        name: "This Work (simulated)",
        tech_nm: cfg.tech_nm,
        gops: rep.gops(),
        power_mw: rep.core_power_w() * 1e3,
        tops_per_w: ours_eff,
        norm_tops_per_w: normalize_efficiency(ours_eff, cfg.tech_nm),
        fps_vgg: rep.fps(),
        compression: "DCT",
    });
    rows
}

pub fn table5_table(rows: &[AccelRow]) -> Table {
    let mut t = Table::new(&[
        "Design",
        "Tech (nm)",
        "GOPS",
        "Power (mW)",
        "TOPS/W",
        "Norm TOPS/W",
        "VGG-16 fps",
        "Fmap Compression",
    ]);
    for r in rows {
        let fps = if r.fps_vgg.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}", r.fps_vgg)
        };
        t.row(&[
            r.name.to_string(),
            format!("{:.0}", r.tech_nm),
            format!("{:.0}", r.gops),
            format!("{:.1}", r.power_mw),
            format!("{:.3}", r.tops_per_w),
            format!("{:.2}", r.norm_tops_per_w),
            fps,
            r.compression.to_string(),
        ]);
    }
    t
}

/// Table V companion: compression-ratio comparison of the baselines on
/// the same feature maps (RLE / CSR / COO vs DCT codec).
pub fn baseline_comparison(seed: u64) -> Table {
    let mut t = Table::new(&[
        "Feature map",
        "DCT codec",
        "RLE",
        "CSR",
        "COO",
    ]);
    for (name, smooth, relu) in [
        ("early (smooth, ReLU)", Smoothness::Natural, true),
        ("mid (mixed, ReLU)", Smoothness::Mixed, true),
        ("deep (abstract, dense)", Smoothness::Abstract, false),
    ] {
        let fmap = natural_image(seed, 8, 56, 56, smooth, relu);
        let dct =
            codec::compress_par(&fmap, &qtable(1)).compression_ratio();
        t.row(&[
            name.to_string(),
            pct(dct),
            pct(baseline::ratio(baseline::rle_bits(&fmap), &fmap)),
            pct(baseline::ratio(baseline::csr_bits(&fmap), &fmap)),
            pct(baseline::ratio(baseline::coo_bits(&fmap), &fmap)),
        ]);
    }
    t
}

/// Wire-format drift companion (printed next to Table III): for each
/// profiled layer, the analytic compression ratio beside the
/// *measured* sealed-stream bytes, so divergence between the ratio
/// model and the packed wire format is visible the moment either
/// changes. With the bitmap scheme the two agree to extrapolation
/// rounding — a non-zero drift column is the regression signal.
/// Takes already-computed profiles so callers don't recompress what
/// they just profiled.
pub fn wire_drift_table(
    net: &Network, prof: &[Option<profiles::LayerProfile>],
) -> Table {
    let mut t = Table::new(&[
        "Layer",
        "Raw",
        "Analytic ratio",
        "Wire bytes (data+index)",
        "Wire ratio",
        "Drift",
    ]);
    for (l, p) in net.layers.iter().zip(prof.iter()) {
        let Some(p) = p else { continue };
        let wire_ratio = p.stored_bytes as f64 / p.raw_bytes as f64;
        let drift = wire_ratio - p.ratio;
        t.row(&[
            l.name.clone(),
            crate::util::human_bytes(p.raw_bytes),
            pct(p.ratio),
            format!(
                "{} ({} + {})",
                crate::util::human_bytes(p.stored_bytes),
                crate::util::human_bytes(p.data_bytes),
                crate::util::human_bytes(p.index_bytes),
            ),
            pct(wire_ratio),
            format!("{:+.4}%", drift * 100.0),
        ]);
    }
    t
}

/// Networks used by the quickstart CLI.
pub fn network_by_name(name: &str) -> Option<Network> {
    let n = match name.to_lowercase().as_str() {
        "vgg16" | "vgg-16-bn" | "vgg" => models::vgg16_bn(),
        "resnet50" | "resnet" => models::resnet50(),
        "yolov3" | "yolo" => models::yolov3(),
        "mobilenetv1" | "mobilenet-v1" => models::mobilenet_v1(),
        "mobilenetv2" | "mobilenet-v2" => models::mobilenet_v2(),
        "smallcnn" => models::smallcnn(),
        _ => return None,
    };
    Some(n)
}

/// Energy breakdown rows (Fig. 15 companion used by the CLI).
pub fn energy_rows(e: &EnergyBreakdown) -> Table {
    let mut t = Table::new(&["Module", "Energy (uJ)", "Share"]);
    let total = e.total_j();
    for (name, j) in e.rows() {
        t.row(&[
            name.to_string(),
            format!("{:.1}", j * 1e6),
            pct(j / total.max(1e-30)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_mentions_headlines() {
        let t = table1(&AccelConfig::default());
        assert!(t.rows_len() >= 12);
    }

    #[test]
    fn table3_shapes() {
        let c = table3(3);
        assert_eq!(c.networks.len(), 5);
        // VGG compresses best overall, MobileNet-v2 worst (paper order)
        let vgg = c
            .networks
            .iter()
            .position(|n| n.contains("VGG"))
            .unwrap();
        let mb2 = c
            .networks
            .iter()
            .position(|n| n.contains("v2"))
            .unwrap();
        assert!(
            c.overall[vgg] < c.overall[mb2],
            "vgg {} mb2 {}",
            c.overall[vgg],
            c.overall[mb2]
        );
    }

    #[test]
    fn table2_savings_positive_for_big_nets() {
        let rows = table2(&AccelConfig::default(), 3);
        let yolo = rows
            .iter()
            .find(|r| r.network.contains("Yolo"))
            .unwrap();
        assert!(yolo.data_reduction_mb > 1.0, "{yolo:?}");
        // DRAM power saved dwarfs the DCT overhead (the paper's point)
        assert!(yolo.power_reduction_mw > yolo.power_overhead_mw);
    }

    #[test]
    fn table5_has_our_row() {
        let rows = table5(&AccelConfig::default(), 3);
        let ours = rows.last().unwrap();
        assert!(ours.name.contains("This Work"));
        assert!(ours.gops > 50.0 && ours.gops < 403.2);
    }

    #[test]
    fn wire_drift_is_negligible_for_the_bitmap_scheme() {
        // The sealed stream *is* what compressed_bits counts, so the
        // only drift is extrapolation rounding. A visible drift here
        // means the wire format and the accounting diverged.
        let net = models::vgg16_bn().with_paper_schedule();
        let prof = profiles::profile_network(&net, 3);
        for p in prof.iter().flatten() {
            let wire = p.stored_bytes as f64 / p.raw_bytes as f64;
            assert!(
                (wire - p.ratio).abs() < 1e-5,
                "wire {wire} vs analytic {}",
                p.ratio
            );
        }
        let t = wire_drift_table(&net, &prof);
        assert!(t.rows_len() >= 10);
    }

    #[test]
    fn lookup_networks() {
        assert!(network_by_name("vgg16").is_some());
        assert!(network_by_name("nope").is_none());
    }
}
