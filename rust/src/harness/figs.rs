//! Figure generators: Fig 14 (area breakdown), Fig 15 (power
//! breakdown), Fig 16 (original vs compressed layer sizes), and the
//! Fig 2-style depth/spectrum motivation. Output is textual (tables +
//! ASCII bars) — the numbers are what the reproduction pins down.

use crate::bench_util::{pct, Table};
use crate::config::{models, AccelConfig};
use crate::data::{natural_image, Smoothness};
use crate::harness::profiles::{self, to_sim_profiles};
use crate::sim::energy::AreaBreakdown;
use crate::sim::Accelerator;

fn bar(frac: f64, width: usize) -> String {
    let n = (frac * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

/// Fig 14 — area breakdown of the accelerator (logic gates).
pub fn fig14(cfg: &AccelConfig) -> Table {
    let a = AreaBreakdown::compute(cfg);
    let total = a.total_gates() as f64;
    let mut t = Table::new(&["Module", "Gates (K)", "Share", ""]);
    for (name, g) in a.rows() {
        let f = g as f64 / total;
        t.row(&[
            name.to_string(),
            format!("{:.0}", g as f64 / 1e3),
            pct(f),
            bar(f, 30),
        ]);
    }
    t.row(&[
        "SRAM (mm^2, separate)".into(),
        format!("{:.2}", a.sram_mm2),
        pct(a.sram_mm2 / a.core_mm2()),
        bar(a.sram_mm2 / a.core_mm2(), 30),
    ]);
    t
}

/// Fig 15 — dynamic power breakdown on a VGG-16-BN run.
pub fn fig15(cfg: &AccelConfig, seed: u64) -> Table {
    let accel = Accelerator::new(cfg.clone());
    let net = models::vgg16_bn().with_paper_schedule();
    let prof = profiles::profile_network(&net, seed);
    let rep = accel.run(&net, &to_sim_profiles(&prof));
    let e = &rep.energy;
    let total = e.total_j();
    let mut t = Table::new(&["Module", "Power (mW)", "Share", ""]);
    let secs = rep.runtime_secs();
    for (name, j) in e.rows() {
        let f = j / total;
        t.row(&[
            name.to_string(),
            format!("{:.1}", j / secs * 1e3),
            pct(f),
            bar(f, 30),
        ]);
    }
    t.row(&[
        "TOTAL (core dynamic)".into(),
        format!("{:.1}", rep.core_power_w() * 1e3),
        pct(1.0),
        String::new(),
    ]);
    t
}

/// One network's Fig 16 series: per-layer original and compressed MB.
pub struct LayerSizes {
    pub network: String,
    pub original_mb: Vec<f64>,
    pub compressed_mb: Vec<f64>,
}

/// Fig 16 — original vs compressed data size of the first 10 layers
/// for VGG-16-BN, ResNet-50, Yolo-v3 and MobileNet-v1 (paper panels
/// a–d).
pub fn fig16(seed: u64) -> Vec<LayerSizes> {
    [
        models::vgg16_bn(),
        models::resnet50(),
        models::yolov3(),
        models::mobilenet_v1(),
    ]
    .into_iter()
    .map(|net| {
        let net = net.with_paper_schedule();
        let prof = profiles::profile_network(&net, seed);
        let mut orig = Vec::new();
        let mut comp = Vec::new();
        for (l, p) in net.layers.iter().zip(prof.iter()).take(10) {
            let raw = l.out_fmap_bytes() as f64 / 1e6;
            orig.push(raw);
            // bypassed layers are stored raw
            comp.push(
                p.map(|p| p.stored_bytes as f64 / 1e6).unwrap_or(raw),
            );
        }
        LayerSizes {
            network: net.name.clone(),
            original_mb: orig,
            compressed_mb: comp,
        }
    })
    .collect()
}

pub fn fig16_table(s: &LayerSizes) -> Table {
    let mut t = Table::new(&[
        "Layer",
        "Original (MB)",
        "Compressed (MB)",
        "Ratio",
    ]);
    for i in 0..s.original_mb.len() {
        t.row(&[
            format!("Fusion {}", i + 1),
            format!("{:.3}", s.original_mb[i]),
            format!("{:.3}", s.compressed_mb[i]),
            pct(s.compressed_mb[i] / s.original_mb[i]),
        ]);
    }
    t
}

/// Fig 2-style motivation: DCT low-frequency energy fraction vs layer
/// depth class — early maps are image-like, deep maps near-white.
pub fn fig2_spectrum(seed: u64) -> Table {
    use crate::compress::dct;
    let mut t = Table::new(&[
        "Depth class",
        "Low-freq energy",
        "Compression ratio @L1",
    ]);
    for (name, s) in [
        ("early (Natural)", Smoothness::Natural),
        ("mid (Mixed)", Smoothness::Mixed),
        ("deep (Abstract)", Smoothness::Abstract),
    ] {
        let fmap = natural_image(seed, 4, 32, 32, s, false);
        // energy in the 4x4 low-frequency corner
        let mut low = 0f64;
        let mut tot = 0f64;
        for ch in 0..fmap.c {
            for br in 0..4 {
                for bc in 0..4 {
                    let mut blk = [0f32; 64];
                    for r in 0..8 {
                        for c in 0..8 {
                            blk[r * 8 + c] =
                                fmap.get(ch, br * 8 + r, bc * 8 + c);
                        }
                    }
                    let z = dct::dct2d(&blk);
                    for (i, v) in z.iter().enumerate() {
                        let e = (*v as f64) * (*v as f64);
                        tot += e;
                        if i / 8 < 4 && i % 8 < 4 {
                            low += e;
                        }
                    }
                }
            }
        }
        let ratio = crate::compress::codec::compress_par(
            &fmap,
            &crate::compress::qtable::qtable(1),
        )
        .compression_ratio();
        t.row(&[name.to_string(), pct(low / tot), pct(ratio)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_has_all_modules() {
        let t = fig14(&AccelConfig::default());
        assert_eq!(t.rows_len(), 8);
    }

    #[test]
    fn fig16_four_networks_ten_layers() {
        let s = fig16(3);
        assert_eq!(s.len(), 4);
        for n in &s {
            assert_eq!(n.original_mb.len(), 10, "{}", n.network);
            // never larger (bypassed layers stay raw), and the big
            // early layers genuinely shrink
            for i in 0..10 {
                assert!(
                    n.compressed_mb[i] <= n.original_mb[i],
                    "{} layer {i}",
                    n.network
                );
            }
            for i in 0..3 {
                assert!(
                    n.compressed_mb[i] < n.original_mb[i] * 0.8,
                    "{} layer {i}",
                    n.network
                );
            }
        }
    }

    #[test]
    fn fig16_vgg_first_layer_large_then_small() {
        let s = fig16(3);
        let vgg = &s[0];
        // conv1_1 output ≈ 6.4 MB raw; compressed below 1.5 MB
        assert!(vgg.original_mb[0] > 4.0);
        assert!(vgg.compressed_mb[0] < 1.5);
    }
}
