//! Per-layer compression profiling: run the real codec over synthetic
//! activations whose smoothness follows the layer's depth (paper
//! Fig. 2), **seal the result to the packed wire format**, and derive
//! the [`CompressionProfile`]s the simulator and the Table II/III/IV
//! benches consume from the sealed stream's byte counts — measured
//! sizes are the accounting source of truth (ROADMAP §Performance),
//! the analytic ratio rides along for drift visibility.

use std::sync::Arc;

use crate::compress::bitstream::{self, FmapBitstream};
use crate::compress::sealed::SealedFmap;
use crate::compress::{codec, qtable::qtable};
use crate::config::{FusionLayer, Network};
use crate::data::{natural_image, Smoothness};
use crate::exec::ExecPool;
use crate::sim::scheduler::{CompressionProfile, StreamMeasure};

/// Measured compression of one layer's output. All byte counts are
/// full-map numbers (sample extrapolated over unsampled channels).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerProfile {
    pub ratio: f64,
    pub nnz_density: f64,
    /// Raw output bytes (16-bit).
    pub raw_bytes: u64,
    /// Stored (sealed) bytes: `data_bytes + index_bytes`.
    pub stored_bytes: u64,
    /// Measured header + value-lane stream bytes.
    pub data_bytes: u64,
    /// Measured index-bitmap stream bytes.
    pub index_bytes: u64,
    pub qlevel: usize,
}

impl LayerProfile {
    /// The hardware bypass rule (§VI-A), shared by every consumer
    /// (harness schedules and the serving coordinator): compression
    /// pays only when the measured wire ratio is below 1.0 —
    /// otherwise the layer is stored raw and the DCT modules are
    /// clock-gated off.
    pub fn pays(&self) -> bool {
        self.ratio < 1.0
    }
}

/// Channels sampled per layer: statistics converge fast across
/// channels, so sampling caps the profiling cost on 400-channel maps.
pub const SAMPLE_CHANNELS: usize = 8;

/// Compress + seal one layer's sampled output map on an explicit
/// pool: the bitstream a profile is derived from, and what the
/// coordinator's interlayer cache stores between layers/requests.
pub fn seal_layer_sample_with_pool(layer: &FusionLayer,
                                   layer_index: usize, qlevel: usize,
                                   seed: u64, depthwise_net: bool,
                                   pool: &ExecPool) -> FmapBitstream {
    let (c, h, w) = layer.out_dims();
    let relu_like = layer.act.sparsifying();
    let smooth = Smoothness::for_layer_arch(
        layer_index,
        !relu_like,
        depthwise_net,
    );
    let sample_c = c.min(SAMPLE_CHANNELS);
    let fmap = natural_image(
        seed ^ (layer_index as u64) << 8,
        sample_c,
        h,
        w,
        smooth,
        relu_like,
    );
    // Pooled codec + pooled seal: bit-identical to the serial paths,
    // so sealed streams stay deterministic given the seed (and
    // pool-size invariant).
    let cf = codec::compress_with_pool(&fmap, &qtable(qlevel), pool);
    bitstream::seal_with_pool(&cf, pool)
}

/// [`seal_layer_sample_with_pool`] on the persistent global pool.
pub fn seal_layer_sample(layer: &FusionLayer, layer_index: usize,
                         qlevel: usize, seed: u64,
                         depthwise_net: bool) -> FmapBitstream {
    seal_layer_sample_with_pool(
        layer,
        layer_index,
        qlevel,
        seed,
        depthwise_net,
        crate::exec::global(),
    )
}

/// [`seal_layer_sample`] wrapped into the pipeline currency: a
/// [`SealedFmap`] handle tagged with the producing layer and Q-level
/// — the form the coordinator ships and caches between stages.
pub fn sealed_layer_sample(layer: &FusionLayer, layer_index: usize,
                           qlevel: usize, seed: u64,
                           depthwise_net: bool) -> SealedFmap {
    SealedFmap::from_bitstream(Arc::new(seal_layer_sample(
        layer,
        layer_index,
        qlevel,
        seed,
        depthwise_net,
    )))
    .with_layer(layer_index)
    .with_qlevel(qlevel)
}

/// Derive the profile straight from a sealed handle — no dense
/// round-trip, the byte counts come off the wire streams. `None`
/// when the handle carries a raw (bypass) payload, which has no
/// compression profile by definition.
pub fn profile_from_sealed(layer: &FusionLayer, sf: &SealedFmap,
                           qlevel: usize) -> Option<LayerProfile> {
    sf.bitstream()
        .map(|bs| profile_from_bitstream(layer, bs, qlevel))
}

/// Derive a [`LayerProfile`] from an already-sealed sample stream —
/// the interlayer cache's hit path: no recompression, the measured
/// byte counts come straight off the wire. Extrapolates the sampled
/// channels to the layer's full channel count.
pub fn profile_from_bitstream(layer: &FusionLayer,
                              bs: &FmapBitstream, qlevel: usize)
                              -> LayerProfile {
    let (c, _, _) = layer.out_dims();
    let sample_c = bs.c.max(1);
    let blocks = bs.blocks() as u64;
    let nnz = bs.value_bytes() / 2;
    let ratio = bs.wire_ratio();
    let nnz_density = if blocks == 0 {
        0.0
    } else {
        nnz as f64 / (blocks * 64) as f64
    };
    let scale = |b: u64| -> u64 {
        (b as f64 * c as f64 / sample_c as f64).ceil() as u64
    };
    let data_bytes = scale(bs.header_bytes() + bs.value_bytes());
    let index_bytes = scale(bs.index_bytes());
    LayerProfile {
        ratio,
        nnz_density,
        raw_bytes: layer.out_fmap_bytes(),
        stored_bytes: data_bytes + index_bytes,
        data_bytes,
        index_bytes,
        qlevel,
    }
}

/// Profile one layer's *output* feature map at a given Q-level, on
/// the persistent global executor pool. `depthwise_net` marks
/// MobileNet-style architectures whose maps decorrelate early (see
/// `Smoothness::for_layer_arch`).
pub fn profile_layer(layer: &FusionLayer, layer_index: usize,
                     qlevel: usize, seed: u64,
                     depthwise_net: bool) -> LayerProfile {
    profile_layer_with_pool(
        layer,
        layer_index,
        qlevel,
        seed,
        depthwise_net,
        crate::exec::global(),
    )
}

/// [`profile_layer`] on an explicit pool — the sampled maps are small
/// (≤ [`SAMPLE_CHANNELS`] channels), so profiling is exactly the
/// many-small-fmap workload the persistent pool amortizes. The
/// profile is measured off the sealed wire stream.
pub fn profile_layer_with_pool(layer: &FusionLayer,
                               layer_index: usize, qlevel: usize,
                               seed: u64, depthwise_net: bool,
                               pool: &ExecPool) -> LayerProfile {
    let bs = seal_layer_sample_with_pool(
        layer,
        layer_index,
        qlevel,
        seed,
        depthwise_net,
        pool,
    );
    profile_from_bitstream(layer, &bs, qlevel)
}

/// Profile a network with its assigned per-layer schedule
/// (`layer.qlevel`) on the persistent global pool; unscheduled layers
/// return None (stored raw).
pub fn profile_network(net: &Network, seed: u64)
                       -> Vec<Option<LayerProfile>> {
    profile_network_with_pool(net, seed, crate::exec::global())
}

/// [`profile_network`] on an explicit pool.
pub fn profile_network_with_pool(net: &Network, seed: u64,
                                 pool: &ExecPool)
                                 -> Vec<Option<LayerProfile>> {
    let dw = net.has_depthwise();
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            l.qlevel
                .map(|q| {
                    profile_layer_with_pool(l, i, q, seed, dw, pool)
                })
                // Bypass: when measured compression does not pay
                // (small/dense maps where padding + index overhead
                // exceed the zero savings), the hardware turns the
                // DCT modules off and stores raw (§VI-A).
                .filter(|p| p.pays())
        })
        .collect()
}

/// Convert to the simulator's profile type, carrying the measured
/// stream footprint so the scheduler accounts real wire bytes.
pub fn to_sim_profiles(profiles: &[Option<LayerProfile>])
                       -> Vec<Option<CompressionProfile>> {
    profiles
        .iter()
        .map(|p| {
            p.map(|p| CompressionProfile {
                ratio: p.ratio,
                nnz_density: p.nnz_density,
                stream: Some(StreamMeasure {
                    data_bytes: p.data_bytes,
                    index_bytes: p.index_bytes,
                }),
            })
        })
        .collect()
}

/// Overall network compression ratio over the *compressed* layers
/// (paper Table III "Overall" row counts the scheduled layers).
pub fn overall_ratio(profiles: &[Option<LayerProfile>]) -> f64 {
    let (mut comp, mut raw) = (0f64, 0f64);
    for p in profiles.iter().flatten() {
        comp += p.stored_bytes as f64;
        raw += p.raw_bytes as f64;
    }
    if raw == 0.0 {
        1.0
    } else {
        comp / raw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;

    #[test]
    fn early_layers_compress_better_than_deep() {
        let net = models::vgg16_bn().with_default_schedule(10);
        let p = profile_network(&net, 42);
        let first = p[0].unwrap().ratio;
        // deepest still-compressed layer of the first ten
        let deep = p[..10]
            .iter()
            .rev()
            .flatten()
            .next()
            .unwrap()
            .ratio;
        assert!(first < deep, "first {first} deep {deep}");
    }

    #[test]
    fn ratios_in_unit_range() {
        // bypass guarantees every surviving profile pays for itself
        let net = models::smallcnn().with_default_schedule(3);
        for p in profile_network(&net, 7).into_iter().flatten() {
            assert!(p.ratio > 0.0 && p.ratio < 1.0, "{}", p.ratio);
            assert!((0.0..=1.0).contains(&p.nnz_density));
        }
    }

    #[test]
    fn tiny_maps_bypass_compression() {
        // SmallCNN f2 output is 64x4x4: padding overhead dominates,
        // so the profiler must mark it uncompressed.
        let net = models::smallcnn().with_default_schedule(3);
        let p = profile_network(&net, 7);
        assert!(p[2].is_none(), "{:?}", p[2]);
    }

    #[test]
    fn unscheduled_layers_are_none() {
        let net = models::vgg16_bn().with_default_schedule(2);
        let p = profile_network(&net, 1);
        assert!(p[0].is_some() && p[1].is_some());
        assert!(p[2..].iter().all(|x| x.is_none()));
    }

    #[test]
    fn overall_ratio_weights_by_size() {
        let net = models::vgg16_bn().with_default_schedule(10);
        let p = profile_network(&net, 3);
        let overall = overall_ratio(&p);
        assert!((0.05..0.9).contains(&overall), "{overall}");
    }

    #[test]
    fn deterministic_given_seed() {
        let net = models::smallcnn().with_default_schedule(3);
        let a = profile_network(&net, 5);
        let b = profile_network(&net, 5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.map(|p| p.stored_bytes),
                       y.map(|p| p.stored_bytes));
        }
    }

    #[test]
    fn pool_size_invariant_profiles() {
        let net = models::smallcnn().with_default_schedule(3);
        let base = profile_network(&net, 5);
        for pool_size in [1usize, 4] {
            let pool = crate::exec::ExecPool::new(pool_size);
            let got = profile_network_with_pool(&net, 5, &pool);
            for (x, y) in base.iter().zip(got.iter()) {
                assert_eq!(x.map(|p| p.stored_bytes),
                           y.map(|p| p.stored_bytes));
                assert_eq!(x.map(|p| p.nnz_density),
                           y.map(|p| p.nnz_density));
            }
        }
    }

    #[test]
    fn sealed_handle_profiles_without_a_dense_roundtrip() {
        let net = models::smallcnn().with_default_schedule(3);
        let dw = net.has_depthwise();
        let l = &net.layers[0];
        let q = l.qlevel.unwrap();
        let sf = sealed_layer_sample(l, 0, q, 7, dw);
        assert_eq!(sf.layer, Some(0));
        assert_eq!(sf.qlevel, Some(q));
        let p = profile_from_sealed(l, &sf, q).unwrap();
        assert_eq!(p, profile_layer(l, 0, q, 7, dw));
        // raw (bypass) handles carry no compression profile
        let raw = crate::compress::sealed::SealedFmap::seal_raw(
            &crate::nn::Tensor3::zeros(1, 4, 4),
        );
        assert!(profile_from_sealed(l, &raw, q).is_none());
    }

    #[test]
    fn profile_measures_the_sealed_stream() {
        // The profile must be derivable from the sealed sample alone
        // (the interlayer cache's hit path) and agree with the
        // analytic ratio within extrapolation rounding.
        let net = models::vgg16_bn().with_default_schedule(4);
        let dw = net.has_depthwise();
        for (i, l) in net.layers.iter().enumerate().take(4) {
            let q = l.qlevel.unwrap();
            let bs = seal_layer_sample(l, i, q, 9, dw);
            let p = profile_from_bitstream(l, &bs, q);
            assert_eq!(p, profile_layer(l, i, q, 9, dw));
            assert_eq!(p.stored_bytes, p.data_bytes + p.index_bytes);
            // measured bytes vs analytic ratio: same wire format, so
            // drift is only the per-stream ceil of the extrapolation
            let analytic = (p.raw_bytes as f64 * p.ratio).ceil();
            let drift =
                (p.stored_bytes as f64 - analytic).abs();
            assert!(
                drift <= 2.0,
                "layer {i}: measured {} vs analytic {analytic}",
                p.stored_bytes
            );
        }
    }
}
