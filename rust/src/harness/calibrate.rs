//! Offline Q-level calibration — the paper's "off-line regression
//! experiment on the test datasets" (§III-B): for every layer, measure
//! the codec's reconstruction SNR at all four Q-levels on
//! depth-representative activations and pick the most aggressive level
//! that stays above a quality floor. Early layers tolerate aggressive
//! tables (large Q values, better ratio); deeper layers get gentle
//! ones — exactly the schedule the 2-bit per-layer register encodes.

use crate::compress::qtable::{calibrate_level, qtable, NUM_LEVELS};
use crate::compress::{codec, BLOCK};
use crate::config::Network;
use crate::data::{natural_image, Smoothness};
use crate::exec::ExecPool;
use crate::harness::profiles::SAMPLE_CHANNELS;

/// Calibration result for one layer.
#[derive(Debug, Clone)]
pub struct LayerCalibration {
    pub layer: String,
    /// Reconstruction SNR (dB) per Q-level.
    pub snr_db: [f64; NUM_LEVELS],
    /// Compression ratio per Q-level.
    pub ratio: [f64; NUM_LEVELS],
    /// Chosen level (most aggressive meeting the floor).
    pub chosen: usize,
    /// Whether the chosen level pays (< 1.0 ratio); otherwise the
    /// layer is stored raw (module power-off).
    pub compress: bool,
}

/// Calibrate every layer of a network against a minimum SNR floor,
/// on the persistent global executor pool.
pub fn calibrate_network(net: &Network, min_snr_db: f64, seed: u64)
                         -> Vec<LayerCalibration> {
    calibrate_network_with_pool(
        net,
        min_snr_db,
        seed,
        crate::exec::global(),
    )
}

/// Calibrate on an explicit pool. The Q-level sweep compresses every
/// sampled map 4× per layer — exactly the many-small-fmap workload the
/// persistent pool amortizes (the seed paid a `thread::scope` spawn
/// for each of those compresses).
pub fn calibrate_network_with_pool(net: &Network, min_snr_db: f64,
                                   seed: u64, pool: &ExecPool)
                                   -> Vec<LayerCalibration> {
    let dw = net.has_depthwise();
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let (c, h, w) = l.out_dims();
            let relu_like = l.act.sparsifying();
            let smooth =
                Smoothness::for_layer_arch(i, !relu_like, dw);
            let fmap = natural_image(
                seed ^ (i as u64) << 8,
                c.min(SAMPLE_CHANNELS),
                h,
                w,
                smooth,
                relu_like,
            );
            let mut snr = [0f64; NUM_LEVELS];
            let mut ratio = [0f64; NUM_LEVELS];
            for level in 0..NUM_LEVELS {
                let qt = qtable(level);
                // One pooled compress per level feeds both metrics
                // (the seed compressed every map twice, serially —
                // calibration was the slowest step of the harness).
                let cf = codec::compress_with_pool(&fmap, &qt, pool);
                ratio[level] = cf.compression_ratio();
                snr[level] = codec::snr_db(
                    &fmap,
                    &codec::decompress_with_pool(&cf, pool),
                );
            }
            let chosen = calibrate_level(&snr, min_snr_db);
            LayerCalibration {
                layer: l.name.clone(),
                snr_db: snr,
                ratio,
                chosen,
                compress: ratio[chosen] < 1.0,
            }
        })
        .collect()
}

/// Apply a calibration to the network's schedule (None = stored raw).
pub fn apply_calibration(mut net: Network,
                         cal: &[LayerCalibration]) -> Network {
    for (l, c) in net.layers.iter_mut().zip(cal.iter()) {
        l.qlevel = if c.compress { Some(c.chosen) } else { None };
    }
    net
}

/// Size-weighted overall ratio the calibrated schedule achieves over
/// its compressed layers.
pub fn calibrated_overall(net: &Network,
                          cal: &[LayerCalibration]) -> f64 {
    let (mut comp, mut raw) = (0f64, 0f64);
    for (l, c) in net.layers.iter().zip(cal.iter()) {
        if c.compress {
            let bytes = l.out_fmap_bytes() as f64;
            raw += bytes;
            comp += bytes * c.ratio[c.chosen];
        }
    }
    if raw == 0.0 {
        1.0
    } else {
        comp / raw
    }
}

/// Mean per-block SNR proxy of a schedule (quality side of the sweep).
pub fn calibrated_mean_snr(cal: &[LayerCalibration]) -> f64 {
    let vals: Vec<f64> = cal
        .iter()
        .filter(|c| c.compress)
        .map(|c| c.snr_db[c.chosen].min(60.0))
        .collect();
    if vals.is_empty() {
        f64::INFINITY
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

const _: () = assert!(BLOCK == 8);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models;

    #[test]
    fn snr_monotone_in_level() {
        let net = models::smallcnn();
        let cal = calibrate_network(&net, 12.0, 3);
        for c in &cal {
            assert!(
                c.snr_db[3] >= c.snr_db[0] - 0.5,
                "{}: {:?}",
                c.layer,
                c.snr_db
            );
        }
    }

    #[test]
    fn stricter_floor_means_gentler_levels() {
        let net = models::vgg16_bn();
        let loose = calibrate_network(&net, 5.0, 3);
        let strict = calibrate_network(&net, 25.0, 3);
        for (a, b) in loose.iter().zip(strict.iter()) {
            assert!(a.chosen <= b.chosen, "{}", a.layer);
        }
    }

    #[test]
    fn stricter_floor_costs_ratio() {
        let net = models::vgg16_bn();
        let loose = calibrate_network(&net, 5.0, 3);
        let strict = calibrate_network(&net, 25.0, 3);
        let r_loose = calibrated_overall(&net, &loose);
        let r_strict = calibrated_overall(&net, &strict);
        assert!(r_loose <= r_strict + 1e-9, "{r_loose} {r_strict}");
        assert!(
            calibrated_mean_snr(&strict)
                >= calibrated_mean_snr(&loose) - 0.5
        );
    }

    #[test]
    fn apply_calibration_sets_schedule() {
        let net = models::smallcnn();
        let cal = calibrate_network(&net, 12.0, 3);
        let net = apply_calibration(net, &cal);
        for (l, c) in net.layers.iter().zip(cal.iter()) {
            assert_eq!(l.qlevel.is_some(), c.compress);
        }
    }

    #[test]
    fn pooled_calibration_is_pool_size_invariant() {
        // Bit-identical pooled codec ⇒ identical calibration
        // decisions for any pool (including size 1).
        let net = models::smallcnn();
        let base = calibrate_network(&net, 12.0, 3);
        for pool_size in [1usize, 3] {
            let pool = crate::exec::ExecPool::new(pool_size);
            let got =
                calibrate_network_with_pool(&net, 12.0, 3, &pool);
            for (a, b) in base.iter().zip(got.iter()) {
                assert_eq!(a.chosen, b.chosen, "{}", a.layer);
                assert_eq!(a.snr_db, b.snr_db, "{}", a.layer);
                assert_eq!(a.ratio, b.ratio, "{}", a.layer);
                assert_eq!(a.compress, b.compress, "{}", a.layer);
            }
        }
    }

    #[test]
    fn early_layers_calibrate_more_aggressive() {
        // the paper's observation encoded: first layers tolerate
        // larger Q values than deep ones at the same quality floor
        let net = models::vgg16_bn();
        let cal = calibrate_network(&net, 18.0, 3);
        assert!(
            cal[0].chosen <= cal[9].chosen,
            "{} vs {}",
            cal[0].chosen,
            cal[9].chosen
        );
    }
}
