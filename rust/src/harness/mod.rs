//! Evaluation harness: regenerates every table and figure of the
//! paper's §VI on the simulated stack (see DESIGN.md §5 for the
//! experiment index).
//!
//! * [`profiles`] — measures per-layer compression profiles by running
//!   the real codec on depth-appropriate synthetic activations.
//! * [`tables`] — Tables I (specs), II (memory-access savings),
//!   III (layer-by-layer compression), IV (vs DAC'20 STC),
//!   V (vs other accelerators).
//! * [`figs`] — Figs 14 (area), 15 (power), 16 (layer sizes),
//!   and the Fig 2-style spectrum motivation.

pub mod calibrate;
pub mod figs;
pub mod profiles;
pub mod tables;
