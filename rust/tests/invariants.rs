//! Cross-module property tests: invariants that tie the codec, the
//! scheduler and the simulator together (the L3 "coordinator
//! invariants" suite — routing of bytes, batching of blocks, state of
//! the buffer bank — exercised over randomized workloads).

use fmc_accel::compress::encode::FlipPacker;
use fmc_accel::compress::{codec, qtable::qtable};
use fmc_accel::config::network::{Act, FusionLayer, LayerKind, Network, Pool};
use fmc_accel::config::{models, AccelConfig};
use fmc_accel::nn::Tensor3;
use fmc_accel::sim::buffer::{BufferBank, MemConfig};
use fmc_accel::sim::scheduler::{self, CompressionProfile};
use fmc_accel::sim::Accelerator;
use fmc_accel::testutil::{check_prop, Prng};

fn rand_fmap(p: &mut Prng, cmax: usize, hw: usize) -> Tensor3 {
    let c = 1 + p.below(cmax);
    let h = 8 + p.below(hw);
    let w = 8 + p.below(hw);
    let mut t = Tensor3::zeros(c, h, w);
    p.fill_normal(&mut t.data, 1.0);
    t
}

#[test]
fn codec_decode_is_exact_inverse_of_encode() {
    // the *lossy* step is quantization; encode/decode of the quantized
    // blocks must be lossless for any input
    check_prop("encode/decode lossless", 25, |p| {
        let x = rand_fmap(p, 6, 40);
        let level = p.below(4);
        let cf = codec::compress(&x, &qtable(level));
        for b in &cf.blocks {
            let q2 = b.decode();
            let re = fmc_accel::compress::encode::EncodedBlock::encode(
                &q2, b.header,
            );
            assert_eq!(re.bitmap, b.bitmap);
            assert_eq!(re.values(), b.values());
        }
    });
}

#[test]
fn codec_roundtrip_is_idempotent() {
    // compressing an already-roundtripped map must reproduce it within
    // one quantization step (stability: no drift across layers)
    check_prop("roundtrip idempotence", 10, |p| {
        let x = rand_fmap(p, 4, 24);
        let qt = qtable(2);
        let once = codec::roundtrip(&x, &qt);
        let twice = codec::roundtrip(&once, &qt);
        let m1 = x.mse(&once);
        let m2 = once.mse(&twice);
        assert!(m2 <= m1 * 1.5 + 1e-6, "drift: {m1} -> {m2}");
    });
}

#[test]
fn compressed_bits_equal_sum_of_parts() {
    check_prop("storage accounting", 15, |p| {
        let x = rand_fmap(p, 4, 32);
        let cf = codec::compress(&x, &qtable(1));
        let parts: u64 = cf
            .blocks
            .iter()
            .map(|b| 64 + 32 + 16 * b.nnz() as u64)
            .sum();
        assert_eq!(cf.compressed_bits(), parts);
    });
}

#[test]
fn flip_packer_conserves_words() {
    check_prop("flip packer conservation", 15, |p| {
        let x = rand_fmap(p, 4, 32);
        let cf = codec::compress(&x, &qtable(p.below(4)));
        let mut packer = FlipPacker::new();
        for b in &cf.blocks {
            packer.push(b);
        }
        assert_eq!(packer.total_words(), cf.nnz());
        assert!(packer.utilization() <= 1.0 + 1e-12);
    });
}

fn rand_network(p: &mut Prng) -> Network {
    let mut layers = Vec::new();
    let mut c = 1 + p.below(8);
    let mut h = 32 + 8 * p.below(8);
    let mut w = h;
    for i in 0..(2 + p.below(6)) {
        let cout = 4 * (1 + p.below(32));
        let stride = if p.below(4) == 0 { 2 } else { 1 };
        let k = [1usize, 3, 3, 3][p.below(4)];
        let l = FusionLayer {
            name: format!("l{i}"),
            kind: LayerKind::Conv,
            cin: c,
            cout,
            h,
            w,
            kernel: k,
            stride,
            padding: k / 2,
            act: Act::Relu,
            pool: Pool::None,
            qlevel: Some(p.below(4)),
        };
        let (nc, nh, nw) = l.out_dims();
        layers.push(l);
        c = nc;
        h = nh;
        w = nw;
        if h < 8 || w < 8 {
            break;
        }
    }
    Network {
        name: "rand".into(),
        layers,
    }
}

#[test]
fn scheduler_plans_are_consistent_with_program() {
    // one plan per layer; spill only when the chosen bank can't hold
    // the stored map; instruction stream has exactly one Conv per layer
    check_prop("scheduler consistency", 20, |p| {
        let net = rand_network(p);
        net.validate().unwrap();
        let cfg = AccelConfig::default();
        let profiles: Vec<Option<CompressionProfile>> = net
            .layers
            .iter()
            .map(|_| {
                Some(CompressionProfile::analytic(
                    0.1 + p.uniform() * 0.9,
                    p.uniform(),
                ))
            })
            .collect();
        let (plans, queue) = scheduler::lower(&cfg, &net, &profiles);
        assert_eq!(plans.len(), net.layers.len());
        assert_eq!(queue.count_convs(), net.layers.len());
        for plan in &plans {
            let bank = BufferBank::new(&cfg, plan.mem);
            let over_in = plan
                .in_stored_bytes
                .saturating_sub(bank.fmap_a() as u64);
            assert_eq!(plan.spill_in_bytes, over_in);
            let over_out = plan
                .out_stored_bytes
                .saturating_sub(bank.fmap_b() as u64);
            assert_eq!(plan.spill_out_bytes, over_out);
            assert!(plan.filter_groups >= 1);
        }
    });
}

#[test]
fn simulator_conserves_macs_and_cycles() {
    // total MACs equal the network's arithmetic regardless of the
    // compression profile; per-layer cycles sum to the total
    check_prop("simulator conservation", 12, |p| {
        let net = rand_network(p);
        let accel = Accelerator::new(AccelConfig::default());
        let r = p.uniform();
        let rep = accel.run_flat(
            &net,
            Some(CompressionProfile::analytic(0.2 + 0.6 * r, r)),
        );
        assert_eq!(rep.stats.macs, net.total_macs());
        let per_layer: u64 =
            rep.layers.iter().map(|l| l.cycles).sum();
        assert_eq!(per_layer, rep.stats.cycles);
        assert!(rep.stats.pe_utilization() <= 1.0 + 1e-12);
    });
}

#[test]
fn better_compression_never_increases_traffic() {
    // monotonicity: a smaller stored ratio can only shrink DRAM bytes
    check_prop("traffic monotone in ratio", 10, |p| {
        let net = models::vgg16_bn();
        let accel = Accelerator::new(AccelConfig::default());
        let a = 0.1 + p.uniform() * 0.4;
        let b = a + p.uniform() * (1.0 - a);
        let run = |r: f64| {
            accel
                .run_flat(
                    &net,
                    Some(CompressionProfile::analytic(r, r)),
                )
                .dram_fmap_bytes()
        };
        assert!(run(a) <= run(b), "ratio {a} vs {b}");
    });
}

#[test]
fn all_mem_configs_preserve_total_sram() {
    let cfg = AccelConfig::default();
    for mc in MemConfig::enumerate() {
        let bank = BufferBank::new(&cfg, mc);
        // fixed parts + all four sub-banks, regardless of attachment
        let total = bank.fmap_a() + bank.fmap_b() + bank.scratch();
        assert_eq!(
            total,
            2 * cfg.fmap_buffer
                + cfg.scratch_base
                + (mc.subbanks_a + mc.subbanks_b + mc.subbanks_scratch)
                    * 32
                    * 1024
        );
    }
}
