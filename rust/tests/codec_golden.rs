//! Cross-language golden test: the rust codec must reproduce the
//! numbers pinned by `python -m compile.golden` (which in turn are the
//! pure-jnp oracle semantics the Pallas kernels are tested against).
//! This is the contract that makes L1/L2/L3 one system.

use fmc_accel::compress::{bitstream, codec, dct, quant, qtable};
use fmc_accel::nn::Tensor3;
use fmc_accel::util::json::Json;

fn golden() -> Json {
    let text = include_str!("golden/codec_golden.json");
    Json::parse(text).expect("golden json parses")
}

fn to_block(v: &Json) -> [f32; 64] {
    let vals = v.f32_vec();
    assert_eq!(vals.len(), 64);
    let mut b = [0f32; 64];
    b.copy_from_slice(&vals);
    b
}

#[test]
fn dct_matrix_matches_python() {
    let g = golden();
    let want = g.get("dct_matrix").f32_vec();
    let c = dct::dct_matrix();
    for k in 0..8 {
        for n in 0..8 {
            let diff = (c[k][n] - want[k * 8 + n]).abs();
            assert!(diff < 1e-6, "C[{k}][{n}]: {diff}");
        }
    }
}

#[test]
fn qtables_match_python() {
    let g = golden();
    for level in 0..4 {
        let want = g.get("qtables").idx(level).f32_vec();
        let got = qtable::qtable(level);
        assert_eq!(&got[..], &want[..], "level {level}");
    }
}

#[test]
fn imax_matches() {
    assert_eq!(golden().get("imax").as_f64(), Some(255.0));
}

#[test]
fn dct_transform_matches_python() {
    let g = golden();
    for case in g.get("cases").as_arr().unwrap() {
        let name = case.get("name").as_str().unwrap();
        let input = to_block(case.get("input"));
        let want = to_block(case.get("dct"));
        let got = dct::dct2d(&input);
        for i in 0..64 {
            assert!(
                (got[i] - want[i]).abs() < 2e-4,
                "{name}[{i}]: rust {} python {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn quantized_codes_match_python_exactly() {
    let g = golden();
    for case in g.get("cases").as_arr().unwrap() {
        let name = case.get("name").as_str().unwrap();
        let input = to_block(case.get("input"));
        let freq = dct::dct2d(&input);
        let (q1, hdr) = quant::gemm_quantize(&freq);
        for lv in case.get("levels").as_arr().unwrap() {
            let level = lv.get("level").as_usize().unwrap();
            let want_q2 = lv.get("q2").f32_vec();
            let want_min = lv.get("fmin").as_f32().unwrap();
            let want_max = lv.get("fmax").as_f32().unwrap();
            assert!(
                (hdr.fmin - want_min).abs() < 2e-4
                    && (hdr.fmax - want_max).abs() < 2e-4,
                "{name} level {level} header"
            );
            let q2 =
                quant::qtable_quantize(&q1, &qtable::qtable(level), &hdr);
            for i in 0..64 {
                assert_eq!(
                    q2[i] as f32, want_q2[i],
                    "{name} level {level} idx {i}"
                );
            }
        }
    }
}

/// The golden feature map: every pinned 8×8 input block as one
/// channel of a (cases, 8, 8) tensor.
fn golden_fmap() -> Tensor3 {
    let g = golden();
    let cases = g.get("cases").as_arr().unwrap();
    let mut t = Tensor3::zeros(cases.len(), 8, 8);
    for (ch, case) in cases.iter().enumerate() {
        let input = to_block(case.get("input"));
        t.channel_mut(ch).copy_from_slice(&input);
    }
    t
}

#[test]
fn compressed_bits_equals_serialized_stream_length() {
    // Satellite regression: `compressed_bits()` is *defined* as 8 ×
    // the serialized stream length. On the golden fmap the legacy
    // arithmetic counter (64-bit bitmap + 32-bit header + one 16-bit
    // word per non-zero) and the measured byte length of the sealed
    // streams must agree exactly, at every Q-level.
    let x = golden_fmap();
    for level in 0..4 {
        let cf = codec::compress(&x, &qtable::qtable(level));
        let legacy: u64 = cf
            .blocks
            .iter()
            .map(|b| 64 + 32 + 16 * b.nnz() as u64)
            .sum();
        assert_eq!(cf.compressed_bits(), legacy, "level {level}");
        let sealed = bitstream::seal(&cf);
        assert_eq!(
            8 * sealed.stream_bytes(),
            legacy,
            "level {level}: wire bytes vs legacy counter"
        );
        // per-stream breakdown is exact too
        assert_eq!(sealed.index_bytes(), 8 * cf.blocks.len() as u64);
        assert_eq!(sealed.header_bytes(), 4 * cf.blocks.len() as u64);
        assert_eq!(sealed.value_bytes(), 2 * cf.nnz());
    }
}

#[test]
fn reconstruction_matches_python() {
    let g = golden();
    for case in g.get("cases").as_arr().unwrap() {
        let name = case.get("name").as_str().unwrap();
        let input = to_block(case.get("input"));
        let freq = dct::dct2d(&input);
        let (q1, hdr) = quant::gemm_quantize(&freq);
        for lv in case.get("levels").as_arr().unwrap() {
            let level = lv.get("level").as_usize().unwrap();
            let want = to_block(lv.get("recon"));
            let qt = qtable::qtable(level);
            let q2 = quant::qtable_quantize(&q1, &qt, &hdr);
            let q1p = quant::qtable_dequantize(&q2, &qt, &hdr);
            let f = quant::gemm_dequantize(&q1p, &hdr);
            let got = dct::idct2d(&f);
            for i in 0..64 {
                assert!(
                    (got[i] - want[i]).abs() < 5e-4,
                    "{name} level {level} idx {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}
