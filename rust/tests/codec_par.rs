//! Property tests for the parallel codec paths and the sparsity-gated
//! inverse transform: `compress_par`/`decompress_par` must be
//! bit-identical to the serial pipeline for any geometry, shard
//! count, and executor-pool size (including 1), the retained
//! spawn-per-call `*_scoped_threads` baseline must match too, and
//! `idct2d_sparse` must match `idct2d_fast` on any coefficient block
//! whose masked-out entries are exactly zero.

use fmc_accel::compress::bitstream;
use fmc_accel::compress::codec::CompressedFmap;
use fmc_accel::compress::encode::{EncodedBlock, FlipPacker};
use fmc_accel::compress::quant::{self, QuantHeader};
use fmc_accel::compress::sealed::SealedFmap;
use fmc_accel::compress::simd::{self, SimdTier};
use fmc_accel::compress::{codec, dct, qtable::qtable};
use fmc_accel::exec::ExecPool;
use fmc_accel::nn::Tensor3;
use fmc_accel::testutil::{check_prop, Prng};

fn rand_fmap(p: &mut Prng, cmax: usize, hw: usize) -> Tensor3 {
    let c = 1 + p.below(cmax);
    let h = 5 + p.below(hw);
    let w = 5 + p.below(hw);
    let mut t = Tensor3::zeros(c, h, w);
    p.fill_normal(&mut t.data, 1.0);
    t
}

#[test]
fn compress_par_bit_identical_across_thread_counts() {
    // Odd geometries (non-multiples of 8, fewer channels than
    // workers) and thread counts 1/2/8: same blocks, same bitmaps,
    // same headers, same cached totals.
    check_prop("compress_par ≡ compress", 20, |p| {
        let x = rand_fmap(p, 9, 40);
        let qt = qtable(p.below(4));
        let serial = codec::compress(&x, &qt);
        for threads in [1usize, 2, 8] {
            let par = codec::compress_with_threads(&x, &qt, threads);
            assert_eq!(
                serial.blocks.len(),
                par.blocks.len(),
                "block count @ {threads}"
            );
            // EncodedBlock's PartialEq covers bitmap, header, values.
            assert_eq!(serial.blocks, par.blocks, "blocks @ {threads}");
            assert_eq!(
                serial.compressed_bits(),
                par.compressed_bits(),
                "bits @ {threads}"
            );
            assert_eq!(serial.nnz(), par.nnz(), "nnz @ {threads}");
            assert_eq!(
                serial.compression_ratio(),
                par.compression_ratio()
            );
        }
    });
}

#[test]
fn decompress_par_bit_identical_across_thread_counts() {
    check_prop("decompress_par ≡ decompress", 15, |p| {
        let x = rand_fmap(p, 9, 40);
        let cf = codec::compress(&x, &qtable(p.below(4)));
        let serial = codec::decompress(&cf);
        for threads in [1usize, 2, 8] {
            let par = codec::decompress_with_threads(&cf, threads);
            assert_eq!(serial.data, par.data, "@ {threads} threads");
        }
    });
}

#[test]
fn pooled_paths_bit_identical_across_pool_sizes() {
    // The persistent-pool path must be bit-identical to serial for
    // every pool size (including 1, where scope jobs run on the
    // joining caller) and every shard count — shard splits depend
    // only on the count, never on which worker runs a shard.
    check_prop("compress/decompress on explicit pools", 10, |p| {
        let x = rand_fmap(p, 9, 40);
        let qt = qtable(p.below(4));
        let serial = codec::compress(&x, &qt);
        let dser = codec::decompress(&serial);
        for pool_size in [1usize, 2, 4] {
            let pool = ExecPool::new(pool_size);
            let par = codec::compress_with_pool(&x, &qt, &pool);
            assert_eq!(
                serial.blocks, par.blocks,
                "compress blocks @ pool {pool_size}"
            );
            assert_eq!(serial.compressed_bits(), par.compressed_bits());
            assert_eq!(serial.nnz(), par.nnz());
            let dpar = codec::decompress_with_pool(&par, &pool);
            assert_eq!(
                dser.data, dpar.data,
                "decompress @ pool {pool_size}"
            );
            // Shard count decoupled from pool size: oversharding a
            // small pool must not change a single bit either.
            let over = codec::compress_sharded(&x, &qt, 7, &pool);
            assert_eq!(
                serial.blocks, over.blocks,
                "compress @ 7 shards on pool {pool_size}"
            );
            let dover = codec::decompress_sharded(&over, 7, &pool);
            assert_eq!(dser.data, dover.data);
        }
    });
}

#[test]
fn scoped_baseline_bit_identical_to_pooled() {
    // The retained spawn-per-call `thread::scope` baseline (what the
    // seed shipped, kept for the bench comparison) and the pooled
    // production path must agree exactly.
    check_prop("scoped ≡ pooled", 10, |p| {
        let x = rand_fmap(p, 9, 40);
        let qt = qtable(p.below(4));
        let pooled = codec::compress_par(&x, &qt);
        for threads in [2usize, 5] {
            let scoped =
                codec::compress_scoped_threads(&x, &qt, threads);
            assert_eq!(pooled.blocks, scoped.blocks, "@ {threads}");
            assert_eq!(
                codec::decompress_par(&pooled).data,
                codec::decompress_scoped_threads(&scoped, threads)
                    .data,
                "decompress @ {threads}"
            );
        }
    });
}

#[test]
fn par_entry_points_match_explicit_thread_counts() {
    // The FMC_THREADS-driven entry points go through the same kernel.
    let mut p = Prng::new(0xFEED);
    let x = rand_fmap(&mut p, 6, 30);
    let qt = qtable(1);
    let serial = codec::compress(&x, &qt);
    let par = codec::compress_par(&x, &qt);
    assert_eq!(serial.blocks, par.blocks);
    assert_eq!(
        codec::decompress(&serial).data,
        codec::decompress_par(&par).data
    );
    assert_eq!(
        codec::roundtrip(&x, &qt).data,
        codec::roundtrip_par(&x, &qt).data
    );
}

fn assert_same_fmap(a: &CompressedFmap, b: &CompressedFmap) {
    assert_eq!(a.blocks, b.blocks);
    assert_eq!((a.c, a.h, a.w), (b.c, b.h, b.w));
    assert_eq!(a.qtable, b.qtable);
    assert_eq!(a.compressed_bits(), b.compressed_bits());
    assert_eq!(a.nnz(), b.nnz());
}

#[test]
fn seal_open_roundtrip_bit_identical_across_pools() {
    // The wire format must reproduce the in-memory codec exactly —
    // same blocks, bitmaps, headers, cached totals — for every shard
    // count and pool size (including 1), and every sharded seal must
    // produce byte-identical streams.
    check_prop("seal/open ≡ id over shards × pools", 10, |p| {
        let x = rand_fmap(p, 9, 40);
        let cf = codec::compress(&x, &qtable(p.below(4)));
        let sealed = bitstream::seal(&cf);
        assert_eq!(
            8 * sealed.stream_bytes(),
            cf.compressed_bits(),
            "serialized length vs storage counter"
        );
        assert_same_fmap(&bitstream::open(&sealed), &cf);
        for pool_size in [1usize, 2, 4] {
            let pool = ExecPool::new(pool_size);
            for shards in [1usize, 2, 7] {
                let s2 = bitstream::seal_sharded(&cf, shards, &pool);
                assert_eq!(
                    sealed, s2,
                    "seal @ {shards} shards on pool {pool_size}"
                );
                let o2 = bitstream::open_sharded(&s2, shards, &pool);
                assert_same_fmap(&o2, &cf);
            }
        }
    });
}

#[test]
fn sealed_fmap_currency_bit_identical_across_shards_and_pools() {
    // The pipeline currency (ISSUE 5): a SealedFmap handle must open
    // to exactly the map the producer sealed — raw payloads bitwise,
    // coded payloads equal to the in-memory decode — for every pool
    // size, and the pooled seal must equal the serial one stream for
    // stream.
    check_prop("SealedFmap open ≡ decode over pools", 8, |p| {
        let x = rand_fmap(p, 8, 36);
        let q = p.below(4);
        let cf = codec::compress(&x, &qtable(q));
        let dense = codec::decompress(&cf);

        let raw = SealedFmap::seal_raw(&x);
        assert_eq!(raw.open().data, x.data, "raw seal lossless");

        let serial = SealedFmap::seal_fmap(&cf, q);
        assert_eq!(serial.open().data, dense.data);
        assert_eq!(
            8 * serial.stream_bytes(),
            cf.compressed_bits(),
            "handle accounts the sealed stream exactly"
        );
        for pool_size in [1usize, 2, 4] {
            let pool = ExecPool::new(pool_size);
            let pooled =
                SealedFmap::seal_fmap_with_pool(&cf, q, &pool);
            assert_eq!(pooled, serial, "seal @ pool {pool_size}");
            assert_eq!(
                pooled.open_with_pool(&pool).data,
                dense.data,
                "open @ pool {pool_size}"
            );
            assert_eq!(
                raw.open_with_pool(&pool).data,
                x.data,
                "raw open @ pool {pool_size}"
            );
        }
    });
}

#[test]
fn sealed_lanes_follow_the_flip_packer_and_stay_level() {
    // Satellite: FlipPacker drives the production stored layout.
    // The sealed value lanes must match the packer model word for
    // word, and flip packing must never utilize the 8 SRAM lanes
    // worse than unflipped packing (it exists to level them).
    check_prop("flip-packed lanes level", 10, |p| {
        let x = rand_fmap(p, 6, 40);
        let cf = codec::compress(&x, &qtable(p.below(4)));
        let flip = bitstream::seal(&cf);
        let mut model = FlipPacker::new();
        for b in &cf.blocks {
            model.push(b);
        }
        for l in 0..8 {
            assert_eq!(
                flip.lane_bytes()[l],
                2 * model.row_occupancy[l],
                "lane {l} vs FlipPacker"
            );
        }
        let noflip = bitstream::seal_unflipped(&cf);
        assert_eq!(flip.value_bytes(), noflip.value_bytes());
        // Quantized DCT spectra are top-heavy, so flipping levels the
        // lanes (small slack absorbs near-symmetric random blocks).
        assert!(
            flip.lane_utilization() >= noflip.lane_utilization() - 0.02,
            "flip {} < noflip {}",
            flip.lane_utilization(),
            noflip.lane_utilization()
        );
        // both layouts reconstruct the same map
        assert_same_fmap(&bitstream::open(&noflip), &cf);
    });
}

#[test]
fn flip_levels_top_heavy_spectra_strictly() {
    // On natural (top-heavy) spectra the flip is a strict win, as in
    // Fig. 5: deterministic smooth map, strictly better utilization.
    let mut x = Tensor3::zeros(4, 32, 32);
    for ch in 0..4 {
        for r in 0..32 {
            for c in 0..32 {
                x.set(
                    ch,
                    r,
                    c,
                    ((r + ch) as f32 * 0.15).sin()
                        + c as f32 * 0.02,
                );
            }
        }
    }
    let cf = codec::compress(&x, &qtable(1));
    let flip = bitstream::seal(&cf);
    let noflip = bitstream::seal_unflipped(&cf);
    assert!(
        flip.lane_utilization() > noflip.lane_utilization(),
        "flip {} vs noflip {}",
        flip.lane_utilization(),
        noflip.lane_utilization()
    );
}

#[test]
fn idct_sparse_matches_fast_on_random_masks() {
    check_prop("idct2d_sparse ≡ idct2d_fast", 50, |p| {
        let mut z = [0f32; 64];
        p.fill_normal(&mut z, 2.0);
        // random density between ~6% and 100%
        let mut keep = u64::MAX;
        for _ in 0..p.below(5) {
            keep &= p.next_u64();
        }
        let mut bm = 0u64;
        for (i, v) in z.iter_mut().enumerate() {
            if keep & (1 << i) == 0 {
                *v = 0.0;
            } else if *v != 0.0 {
                bm |= 1 << i;
            }
        }
        let dense = dct::idct2d_fast(&z);
        let sparse = dct::idct2d_sparse(&z, bm);
        assert_eq!(sparse, dense, "bitmap {bm:#018x}");
    });
}

#[test]
fn idct_sparse_corner_bitmaps() {
    let mut p = Prng::new(31);
    let mut z = [0f32; 64];
    p.fill_normal(&mut z, 1.0);
    // dense bitmap on a dense block
    assert_eq!(dct::idct2d_sparse(&z, u64::MAX), dct::idct2d_fast(&z));
    // all-zero block with empty bitmap
    assert_eq!(dct::idct2d_sparse(&[0f32; 64], 0), [0f32; 64]);
    // empty bitmap must win over stale coefficients per the contract:
    // callers guarantee cleared bits are zero, so pass a zero block
    let zeros = [0f32; 64];
    assert_eq!(dct::idct2d_sparse(&zeros, 0), dct::idct2d_fast(&zeros));
}

// --- SIMD dispatch tiers (ISSUE 8) -----------------------------------
//
// Every tier in `simd::available()` must be BIT-identical to the
// Scalar tier (which delegates to the untouched reference kernels):
// f32 outputs are compared through `to_bits`, so even a `-0.0` vs
// `+0.0` divergence fails. The `FMC_SIMD` CI matrix legs rerun this
// whole file under forced tiers; these tests additionally sweep every
// runnable tier inside one process via the explicit-tier APIs.

fn bits64(b: &[f32; 64]) -> Vec<u32> {
    b.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn simd_transforms_bit_identical_across_tiers() {
    let tiers = simd::available();
    check_prop("simd dct2d/idct2d ≡ scalar", 40, |p| {
        let mut z = [0f32; 64];
        p.fill_normal(&mut z, 2.0);
        let mut fwd = z;
        simd::dct2d_fast_inplace(SimdTier::Scalar, &mut fwd);
        let mut inv = z;
        simd::idct2d_fast_inplace(SimdTier::Scalar, &mut inv);
        for &t in &tiers {
            let mut f = z;
            simd::dct2d_fast_inplace(t, &mut f);
            assert_eq!(bits64(&f), bits64(&fwd), "dct2d [{}]", t.name());
            let mut i = z;
            simd::idct2d_fast_inplace(t, &mut i);
            assert_eq!(bits64(&i), bits64(&inv), "idct2d [{}]", t.name());
        }
    });
}

#[test]
fn simd_sparse_idct_bit_identical_across_tiers() {
    let tiers = simd::available();
    check_prop("simd idct2d_sparse ≡ scalar", 40, |p| {
        let mut z = [0f32; 64];
        p.fill_normal(&mut z, 2.0);
        // Random density from ~6% to 100% (same recipe as the
        // sparse ≡ dense test above), honoring the contract that
        // cleared bits are exactly-zero coefficients.
        let mut keep = u64::MAX;
        for _ in 0..p.below(5) {
            keep &= p.next_u64();
        }
        let mut bm = 0u64;
        for (i, v) in z.iter_mut().enumerate() {
            if keep & (1 << i) == 0 {
                *v = 0.0;
            } else if *v != 0.0 {
                bm |= 1 << i;
            }
        }
        let mut want = [0f32; 64];
        simd::idct2d_sparse_into(SimdTier::Scalar, &z, bm, &mut want);
        for &t in &tiers {
            // Dirty output buffer: the kernel must overwrite every
            // position, including gated-to-zero ones.
            let mut got = [7.25f32; 64];
            simd::idct2d_sparse_into(t, &z, bm, &mut got);
            assert_eq!(
                bits64(&got),
                bits64(&want),
                "sparse idct [{}] bitmap {bm:#018x}",
                t.name()
            );
        }
    });
    // All-zero bitmap edge: every tier must produce exact +0.0
    // everywhere, regardless of buffer garbage.
    for &t in &simd::available() {
        let mut got = [3.5f32; 64];
        simd::idct2d_sparse_into(t, &[0f32; 64], 0, &mut got);
        assert_eq!(bits64(&got), vec![0u32; 64], "zero bitmap [{}]", t.name());
    }
}

#[test]
fn simd_quant_kernels_bit_identical_across_tiers() {
    let tiers = simd::available();
    check_prop("simd quantize/dequantize ≡ scalar", 40, |p| {
        let mut freq = [0f32; 64];
        p.fill_normal(&mut freq, 3.0);
        let qt = qtable(p.below(4));
        let raw = quant::block_extrema(&freq);
        // A narrowed header makes both clamp rails engage and drives
        // `rint` through negative-tiny inputs (the `-0.0` cases the
        // vector clamp must preserve exactly).
        let narrowed = QuantHeader {
            fmin: raw.fmin + 0.25 * raw.span(),
            fmax: raw.fmax - 0.25 * raw.span(),
        };
        for hdr in [raw, narrowed] {
            let mut want_q1 = [0f32; 64];
            quant::gemm_quantize_with_into(&freq, &hdr, &mut want_q1);
            let want_q2 = quant::qtable_quantize(&want_q1, &qt, &hdr);
            let want_q1p = quant::qtable_dequantize(&want_q2, &qt, &hdr);
            let want_f = quant::gemm_dequantize(&want_q1p, &hdr);
            for &t in &tiers {
                let mut q1 = [0f32; 64];
                simd::gemm_quantize_with_into(t, &freq, &hdr, &mut q1);
                assert_eq!(
                    bits64(&q1),
                    bits64(&want_q1),
                    "gemm_quantize [{}]",
                    t.name()
                );
                let mut q2 = [0i16; 64];
                simd::qtable_quantize_into(t, &q1, &qt, &hdr, &mut q2);
                assert_eq!(q2, want_q2, "qtable_quantize [{}]", t.name());
                let mut q1p = [0f32; 64];
                simd::qtable_dequantize_into(t, &q2, &qt, &hdr, &mut q1p);
                assert_eq!(
                    bits64(&q1p),
                    bits64(&want_q1p),
                    "qtable_dequantize [{}]",
                    t.name()
                );
                let mut f = [0f32; 64];
                simd::gemm_dequantize_into(t, &q1p, &hdr, &mut f);
                assert_eq!(
                    bits64(&f),
                    bits64(&want_f),
                    "gemm_dequantize [{}]",
                    t.name()
                );
            }
        }
        // Degenerate span: every tier must wipe the scratch to zero.
        let flat = QuantHeader { fmin: 1.0, fmax: 1.0 };
        for &t in &tiers {
            let mut q1 = [9f32; 64];
            simd::gemm_quantize_with_into(t, &freq, &flat, &mut q1);
            assert_eq!(bits64(&q1), vec![0u32; 64], "degenerate [{}]", t.name());
        }
    });
}

#[test]
fn simd_block_extrema_bit_identical_across_tiers() {
    let tiers = simd::available();
    check_prop("block_extrema per tier ≡ scalar", 40, |p| {
        let mut freq = [0f32; 64];
        p.fill_normal(&mut freq, 3.0);
        let want = quant::block_extrema(&freq);
        for &t in &tiers {
            let got = simd::block_extrema(t, &freq);
            assert_eq!(
                (got.fmin.to_bits(), got.fmax.to_bits()),
                (want.fmin.to_bits(), want.fmax.to_bits()),
                "extrema [{}]",
                t.name()
            );
        }
    });
    // Signed-zero extrema: packed minps/maxps keep whichever operand
    // of a `+0.0`/`-0.0` pair the fold order hands them, so a block
    // whose true min or max is a zero exercises the vector tiers'
    // scalar-rescan fallback. Sweep both orderings of the pair across
    // lane/row positions so every fold path sees each flavor first.
    for (a, b) in [(0.0f32, -0.0f32), (-0.0f32, 0.0f32)] {
        for pos in [0usize, 3, 7, 8, 31, 32, 60, 63] {
            // Zero is the minimum of an otherwise-positive block.
            let mut f = [1.5f32; 64];
            f[pos] = a;
            f[63 - pos] = b;
            let want = quant::block_extrema(&f);
            for &t in &tiers {
                let got = simd::block_extrema(t, &f);
                assert_eq!(
                    (got.fmin.to_bits(), got.fmax.to_bits()),
                    (want.fmin.to_bits(), want.fmax.to_bits()),
                    "zero-min [{}] pos {pos} pair ({a},{b})",
                    t.name()
                );
            }
            // Zero is the maximum of an otherwise-negative block.
            let mut g = [-1.5f32; 64];
            g[pos] = a;
            g[63 - pos] = b;
            let want = quant::block_extrema(&g);
            for &t in &tiers {
                let got = simd::block_extrema(t, &g);
                assert_eq!(
                    (got.fmin.to_bits(), got.fmax.to_bits()),
                    (want.fmin.to_bits(), want.fmax.to_bits()),
                    "zero-max [{}] pos {pos} pair ({a},{b})",
                    t.name()
                );
            }
        }
    }
    // All-zero block of mixed flavors: both extrema land on zero.
    let mut z = [0.0f32; 64];
    for v in z.iter_mut().skip(1).step_by(2) {
        *v = -0.0;
    }
    let want = quant::block_extrema(&z);
    for &t in &tiers {
        let got = simd::block_extrema(t, &z);
        assert_eq!(
            (got.fmin.to_bits(), got.fmax.to_bits()),
            (want.fmin.to_bits(), want.fmax.to_bits()),
            "all-zero [{}]",
            t.name()
        );
    }
}

#[test]
fn simd_seal_open_bit_identical_across_tiers() {
    let tiers = simd::available();
    check_prop("seal/open per tier ≡ scalar", 10, |p| {
        let x = rand_fmap(p, 6, 40);
        let cf = codec::compress(&x, &qtable(p.below(4)));
        let want = bitstream::seal_with_simd(&cf, SimdTier::Scalar);
        // The production entry point (whatever tier FMC_SIMD picked)
        // must sit on the same byte stream.
        assert_eq!(want, bitstream::seal(&cf), "active-tier seal");
        for &t in &tiers {
            let s = bitstream::seal_with_simd(&cf, t);
            assert_eq!(want, s, "seal [{}]", t.name());
            assert_same_fmap(
                &bitstream::open_with_simd(&want, t),
                &cf,
            );
        }
    });
}

#[test]
fn dispatched_compress_matches_scalar_composition() {
    // End-to-end anchor: on an 8×8 single-block map the fused codec
    // kernel reduces to the public scalar reference pipeline
    // (dct → snap → Eq.7 → Eq.8 → encode). The dispatched compress —
    // under whatever tier FMC_SIMD selected — must reproduce it bit
    // for bit, proving the dispatch seam changes nothing observable.
    check_prop("compress ≡ scalar composition", 20, |p| {
        let mut x = Tensor3::zeros(1, 8, 8);
        p.fill_normal(&mut x.data, 1.0);
        let qt = qtable(p.below(4));
        let cf = codec::compress(&x, &qt);

        let mut tile = [0f32; 64];
        tile.copy_from_slice(x.channel(0));
        dct::dct2d_fast_inplace(&mut tile);
        let hdr = bitstream::snap_header(quant::block_extrema(&tile));
        let mut q1 = [0f32; 64];
        quant::gemm_quantize_with_into(&tile, &hdr, &mut q1);
        let q2 = quant::qtable_quantize(&q1, &qt, &hdr);
        let mut want = EncodedBlock::default();
        want.encode_from(&q2, hdr);

        assert_eq!(cf.blocks.len(), 1);
        assert_eq!(cf.blocks[0], want);
    });
}
