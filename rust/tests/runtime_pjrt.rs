//! PJRT runtime integration tests. Require the `pjrt` feature (the
//! whole file is compiled out on the default stub build, where every
//! execution entry point errors by design) plus `make artifacts`;
//! each test skips (prints a notice) when artifacts are absent so
//! `cargo test --features pjrt` stays green on a clean checkout.
#![cfg(feature = "pjrt")]

use fmc_accel::compress::{codec, dct, quant, qtable::qtable};
use fmc_accel::data;
use fmc_accel::runtime::Runtime;
use fmc_accel::testutil::Prng;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(r) => Some(r),
        Err(_) => {
            eprintln!("artifacts/ missing — run `make artifacts`; skipping");
            None
        }
    }
}

#[test]
fn pjrt_compress_matches_rust_codec() {
    let Some(mut rt) = runtime() else { return };
    let mut p = Prng::new(77);
    let mut blocks = vec![0f32; 16 * 64];
    p.fill_normal(&mut blocks, 2.0);
    let qt = qtable(2);
    let (q2, mn, mx) = rt.dct_compress(&blocks, &qt).unwrap();
    let mut exact = 0;
    for b in 0..16 {
        let blk: [f32; 64] =
            blocks[b * 64..(b + 1) * 64].try_into().unwrap();
        let freq = dct::dct2d(&blk);
        let (q1, hdr) = quant::gemm_quantize(&freq);
        let want = quant::qtable_quantize(&q1, &qt, &hdr);
        assert!((mn[b] - hdr.fmin).abs() < 1e-4);
        assert!((mx[b] - hdr.fmax).abs() < 1e-4);
        for i in 0..64 {
            let diff = (q2[b * 64 + i] - want[i] as f32).abs();
            assert!(diff <= 1.0, "block {b} idx {i}: diff {diff}");
            if diff == 0.0 {
                exact += 1;
            }
        }
    }
    // XLA einsum may differ at exact rounding boundaries only
    assert!(exact >= 16 * 64 * 9 / 10, "{exact}/1024 exact");
}

#[test]
fn pjrt_roundtrip_reconstruction_bounded() {
    let Some(mut rt) = runtime() else { return };
    let mut p = Prng::new(78);
    let mut blocks = vec![0f32; 8 * 64];
    p.fill_normal(&mut blocks, 1.0);
    let qt = qtable(3);
    let (q2, mn, mx) = rt.dct_compress(&blocks, &qt).unwrap();
    let rec = rt.dct_decompress(&q2, &mn, &mx, &qt).unwrap();
    // gentlest table: bounded distortion on unit-normal data
    let max_err = rec
        .iter()
        .zip(blocks.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1.5, "max err {max_err}");
}

#[test]
fn pjrt_classify_compressed_matches_labels() {
    let Some(mut rt) = runtime() else { return };
    let batch = data::shapes_batch(31, 4, 32);
    let images: Vec<_> = batch.iter().map(|(i, _)| i.clone()).collect();
    let res = rt.classify(&images, true).unwrap();
    let correct = res
        .iter()
        .zip(batch.iter())
        .filter(|((c, _), (_, l))| c == l)
        .count();
    assert!(correct >= 3, "{correct}/4 with compressed model");
}

#[test]
fn pjrt_compressed_and_plain_models_agree() {
    // The interlayer codec must not flip classifications vs the
    // uncompressed model (the <1% accuracy-loss property, per batch).
    let Some(mut rt) = runtime() else { return };
    let batch = data::shapes_batch(32, 4, 32);
    let images: Vec<_> = batch.iter().map(|(i, _)| i.clone()).collect();
    let plain = rt.classify(&images, false).unwrap();
    let comp = rt.classify(&images, true).unwrap();
    let agree = plain
        .iter()
        .zip(comp.iter())
        .filter(|((a, _), (b, _))| a == b)
        .count();
    assert!(agree >= 3, "{agree}/4 agreement");
}

#[test]
fn pjrt_rejects_oversized_batch() {
    let Some(mut rt) = runtime() else { return };
    let batch = data::shapes_batch(33, 9, 32);
    let images: Vec<_> = batch.iter().map(|(i, _)| i.clone()).collect();
    assert!(rt.classify(&images, true).is_err());
}

#[test]
fn pjrt_fusion_layer_matches_golden_model() {
    // The L2 fusion-layer artifact (conv->BN->ReLU->pool->codec) must
    // match the L3 golden pipeline built from nn:: + compress::.
    use fmc_accel::nn::{self, Tensor3, Weights};

    let Some(mut rt) = runtime() else { return };
    let mut p = Prng::new(99);
    let (cin, cout, hw) = (16usize, 32usize, 32usize);
    let mut x = Tensor3::zeros(cin, hw, hw);
    p.fill_normal(&mut x.data, 1.0);
    let mut w = vec![0f32; cout * cin * 9];
    p.fill_normal(&mut w, 0.1);
    let mut scale = vec![0f32; cout];
    let mut bias = vec![0f32; cout];
    for i in 0..cout {
        scale[i] = 0.5 + p.uniform() as f32;
        bias[i] = p.normal() as f32 * 0.1;
    }

    let got = rt.fusion_layer(&x, &w, &scale, &bias).unwrap();

    // golden: conv -> BN -> ReLU -> maxpool -> codec roundtrip @ Q1
    let wt = Weights::from_vec(cout, cin, 3, w.clone());
    let mut y = nn::conv2d(&x, &wt, 1, 1);
    nn::batch_norm(&mut y, &scale, &bias);
    nn::activate(&mut y, nn::Activation::Relu);
    let y = nn::max_pool2x2(&y);
    let want = codec::roundtrip(&y, &qtable(1));

    assert_eq!((got.c, got.h, got.w), (want.c, want.h, want.w));
    // lossy codec differs at rounding boundaries between the XLA and
    // rust DCT accumulation orders; bound the disagreement instead
    let scale_abs = want.max_abs().max(1.0);
    let mut worst = 0f32;
    for (a, b) in got.data.iter().zip(want.data.iter()) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst <= 0.15 * scale_abs, "worst {worst} of {scale_abs}");
}
