//! Concurrency stress tests for the multi-worker serving pipeline:
//! many client threads hammering one batcher + N workers must lose no
//! request, the merged shutdown metrics must equal the per-worker
//! sums, requests arriving during an idle window must still coalesce
//! under the batching policy, and failure paths (no live workers,
//! dead batcher) must surface as errors instead of hangs.
//!
//! The tests inject synthetic [`InferenceEngine`]s so the pipeline
//! runs without PJRT artifacts; `sim_profile` is pinned so startup
//! skips the codec profiling pass.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use fmc_accel::coordinator::{
    BatchPolicy, EngineFactory, InferenceEngine, InferenceServer,
    InterlayerCache, Metrics, ServerConfig,
};
use fmc_accel::nn::Tensor3;
use fmc_accel::sim::scheduler::CompressionProfile;

/// Deterministic synthetic engine: class = (first pixel) mod 7, and
/// the first logit echoes the pixel so clients can verify routing.
/// Per-engine counters let the tests check the merged metrics against
/// per-worker sums.
struct TagEngine {
    cap: usize,
    images: Arc<AtomicUsize>,
    batches: Arc<AtomicUsize>,
}

impl InferenceEngine for TagEngine {
    fn max_batch(&self) -> usize {
        self.cap
    }

    fn infer(&mut self, images: &[Tensor3])
             -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(images.len(), Ordering::Relaxed);
        Ok(images
            .iter()
            .map(|im| {
                let tag = im.data[0] as usize;
                (tag % 7, vec![tag as f32])
            })
            .collect())
    }
}

fn tagged_image(tag: usize) -> Tensor3 {
    let mut t = Tensor3::zeros(1, 2, 2);
    t.data[0] = tag as f32; // exact for tag < 2^24
    t
}

fn stress_config(workers: usize) -> ServerConfig {
    let mut cfg =
        ServerConfig::new("/nonexistent-artifacts-not-used")
            .with_workers(workers);
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(2),
    };
    // Pin the hardware-accounting profile so startup skips the codec
    // profiling measurement (not under test here).
    cfg.sim_profile = Some(CompressionProfile::uncompressed());
    cfg
}

#[test]
fn eight_submitters_three_workers_lose_nothing() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 40;
    const WORKERS: usize = 3;

    let counters: Vec<(Arc<AtomicUsize>, Arc<AtomicUsize>)> = (0
        ..WORKERS)
        .map(|_| {
            (
                Arc::new(AtomicUsize::new(0)),
                Arc::new(AtomicUsize::new(0)),
            )
        })
        .collect();
    let factory_counters = counters.clone();
    let factory: EngineFactory = Arc::new(move |wi: usize| {
        let (images, batches) = factory_counters[wi].clone();
        Ok(Box::new(TagEngine {
            cap: 4,
            images,
            batches,
        }) as Box<dyn InferenceEngine>)
    });

    let server = InferenceServer::start_with_engines(
        stress_config(WORKERS),
        factory,
    )
    .expect("server start");

    std::thread::scope(|s| {
        for client in 0..CLIENTS {
            let server = &server;
            s.spawn(move || {
                let base = client * PER_CLIENT;
                let rxs: Vec<_> = (0..PER_CLIENT)
                    .map(|i| {
                        server
                            .submit(tagged_image(base + i))
                            .expect("submit while running")
                    })
                    .collect();
                for (i, rx) in rxs.into_iter().enumerate() {
                    let tag = base + i;
                    let resp = rx
                        .recv_timeout(Duration::from_secs(30))
                        .expect("response within 30s");
                    assert_eq!(resp.class, tag % 7, "class for {tag}");
                    assert_eq!(
                        resp.logits[0], tag as f32,
                        "logit echo for {tag}"
                    );
                }
            });
        }
    });

    let metrics = server.shutdown();
    let total = CLIENTS * PER_CLIENT;
    let worker_images: usize = counters
        .iter()
        .map(|(im, _)| im.load(Ordering::Relaxed))
        .sum();
    let worker_batches: usize = counters
        .iter()
        .map(|(_, b)| b.load(Ordering::Relaxed))
        .sum();

    assert_eq!(metrics.requests, total as u64, "no lost requests");
    assert_eq!(metrics.errors, 0);
    // Merged shutdown metrics must equal the per-worker sums.
    assert_eq!(worker_images, total);
    assert_eq!(metrics.batches, worker_batches as u64);
    // max_batch = 4 bounds the batch count from below.
    assert!(
        metrics.batches >= (total / 4) as u64,
        "batches {} < {}",
        metrics.batches,
        total / 4
    );
    // Batch-level round-robin sharding: every worker saw work.
    for (wi, (im, _)) in counters.iter().enumerate() {
        assert!(
            im.load(Ordering::Relaxed) > 0,
            "worker {wi} never ran a batch"
        );
    }
}

/// One run of the post-idle burst scenario; returns the merged batch
/// count for 4 requests submitted back-to-back during an idle window.
fn post_idle_burst_batches() -> u64 {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let mut cfg = stress_config(1);
    // A linger long enough that a back-to-back burst normally lands
    // well inside it.
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(200),
    };
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    // Let the batcher pass through at least one idle poll window
    // (IDLE_POLL is 200ms).
    std::thread::sleep(Duration::from_millis(500));
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 4);
    metrics.batches
}

#[test]
fn idle_arrivals_still_coalesce() {
    // Satellite regression: the seed's idle fallback handled the
    // first post-idle request with a raw `recv` outside the batching
    // policy, producing a singleton batch — so a post-idle burst of 4
    // could NEVER land in one batch. The fixed dispatch loop routes
    // it back through poll_batch, so the burst normally coalesces
    // into exactly one policy-shaped batch. A bounded retry absorbs
    // the rare CI case where the client thread is descheduled past
    // the 200ms linger mid-burst.
    for attempt in 0..3 {
        if post_idle_burst_batches() == 1 {
            return;
        }
        eprintln!("attempt {attempt}: burst split by scheduling");
    }
    panic!(
        "post-idle bursts never coalesced into one batch in 3 runs"
    );
}

/// One server run with measured (sealed-stream) hardware accounting
/// through a shared interlayer bitstream cache; returns the response
/// payloads relevant to accounting plus the shutdown metrics.
fn run_accounted_server(
    cache: Arc<Mutex<InterlayerCache>>,
) -> (Vec<(usize, u64, f64)>, Metrics) {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let mut cfg =
        ServerConfig::new("/nonexistent-artifacts-not-used")
            .with_workers(1)
            .with_cache(cache);
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(2),
    };
    cfg.compressed = true;
    cfg.sim_profile = None; // measure through the sealed streams
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("accounted response");
            (r.class, r.sim_cycles, r.sim_energy_j)
        })
        .collect();
    (resps, server.shutdown())
}

#[test]
fn cache_hit_responses_equal_cache_miss_responses() {
    // Satellite: the interlayer bitstream cache must be semantically
    // invisible — a server whose profiling pass *hits* the cache
    // (sealed streams reused, no recompression) answers with exactly
    // the same classes and simulated-hardware accounting as the
    // server that sealed everything from scratch.
    let cache = Arc::new(Mutex::new(InterlayerCache::new(
        64 * 1024 * 1024,
    )));
    let (miss_resps, miss_metrics) =
        run_accounted_server(cache.clone());
    let after_miss = cache.lock().unwrap().stats();
    assert!(after_miss.misses > 0, "first run must seal streams");
    assert_eq!(after_miss.hits, 0);
    assert!(after_miss.bytes_held > 0, "streams retained");
    assert!(miss_metrics.cache_misses > 0);
    assert_eq!(miss_metrics.cache_hits, 0);

    let (hit_resps, hit_metrics) =
        run_accounted_server(cache.clone());
    let after_hit = cache.lock().unwrap().stats();
    assert_eq!(
        after_hit.misses, after_miss.misses,
        "hit path must not reseal"
    );
    assert!(hit_metrics.cache_hits > 0);
    assert_eq!(hit_metrics.cache_misses, 0);
    assert_eq!(
        miss_resps, hit_resps,
        "cache-hit responses must equal cache-miss responses"
    );
}

/// Drive a server whose workers can never start: submits must begin
/// failing once the batcher exits (the seed's `let _ = tx.send(..)`
/// accepted requests into the void forever), and any request that did
/// get queued first must error out, not hang. Returns the shutdown
/// metrics for failure-accounting assertions.
fn drive_dead_server(server: InferenceServer) -> u64 {
    let deadline =
        std::time::Instant::now() + Duration::from_secs(30);
    let mut queued = Vec::new();
    loop {
        match server.submit(tagged_image(0)) {
            Err(_) => break, // batcher observed dead: correct
            Ok(rx) => {
                queued.push(rx);
                assert!(
                    std::time::Instant::now() < deadline,
                    "submit kept succeeding with no live workers"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    for rx in queued {
        assert!(
            rx.recv_timeout(Duration::from_secs(30)).is_err(),
            "queued request must error, not hang"
        );
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 0);
    metrics.errors
}

#[test]
fn no_live_workers_makes_submit_fail_fast() {
    // Every engine construction fails cleanly: both workers report
    // their error, the batcher exits, submit starts erroring.
    let factory: EngineFactory = Arc::new(|wi: usize| {
        anyhow::bail!("engine {wi} unavailable")
    });
    let server = InferenceServer::start_with_engines(
        stress_config(2),
        factory,
    )
    .unwrap();
    let errors = drive_dead_server(server);
    assert_eq!(errors, 2, "one error per failed worker");
}

#[test]
fn panicking_engine_factory_is_contained() {
    // The factory panics on the worker thread; the batcher counts the
    // startup death and exits, and submit surfaces the dead server.
    let factory: EngineFactory = Arc::new(|_: usize| {
        panic!("engine construction panic (test)")
    });
    let server = InferenceServer::start_with_engines(
        stress_config(1),
        factory,
    )
    .unwrap();
    let errors = drive_dead_server(server);
    assert_eq!(errors, 1, "one error for the dead worker");
}
