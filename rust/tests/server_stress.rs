//! Concurrency stress tests for the multi-worker serving pipeline:
//! many client threads hammering one batcher + N workers must lose no
//! request, the merged shutdown metrics must equal the per-worker
//! sums, requests arriving during an idle window must still coalesce
//! under the batching policy, and failure paths (no live workers,
//! dead batcher) must surface as errors instead of hangs.
//!
//! Compressed-domain dataflow (ISSUE 5): the sealed-transport path —
//! batcher ships sealed envelopes, workers open at the engine
//! boundary, staged engines ship sealed interlayer maps — must return
//! **bit-identical** responses to the dense reference path for every
//! worker count (shard/pool invariance of the underlying seal/open is
//! property-tested in `codec_par.rs` and `compress::sealed`), the
//! in-flight stage measures must drive the scheduler with no re-seal,
//! and the `InterlayerCache` must keep exact byte accounting under
//! concurrent workers.
//!
//! Telemetry (ISSUE 6): every request's [`fmc_accel::obs::Span`] must
//! cover the full stage sequence with the five seams exactly
//! partitioning the end-to-end interval, the per-worker span rings
//! must keep exact recorded/dropped/buffered accounting under
//! overflow, the Chrome trace export must carry one complete slice
//! sequence per request, and the executor pool's lifetime counters
//! must balance (submitted == executed) after every join.
//!
//! Robustness (ISSUE 7): the chaos suite at the bottom drives the
//! bounded admission queue to typed `QueueFull` sheds, propagates
//! deadlines to the batch and open seams, kills workers mid-run with
//! deterministic [`FaultPlan`]s and requires the in-flight requeue to
//! deliver every reply exactly once and bit-identical to the
//! fault-free run, and property-checks the conservation identity
//! `submitted == replied + shed_* + failed` under churn.
//!
//! Tiered store (ISSUE 10): the RAM interlayer cache is now the top
//! tier of a [`fmc_accel::store::TieredStore`] whose evictions spill
//! to a paged disk tier instead of dropping. The store tests below
//! require the tri-identity — a disk-tier hit answers bit-identical
//! to a RAM hit and to a cold re-seal — and hammer the spill /
//! backfill path from many threads, gating the exact byte accounting
//! plus the tier-hit conservation identity
//! `ram_hits + disk_hits + misses == lookups`.
//!
//! Sharded front door (ISSUE 9): the single batcher is gone —
//! submits land in per-worker bounded shards and workers pull and
//! form their own batches, stealing whole batches from sibling
//! shards when idle. The tests at the bottom pin the new seam: a
//! saturated shard drains through sibling steals, a shard that sheds
//! an entire pulled batch on deadline still coalesces the next
//! burst, and a seeded churn sweep requires the sharded door to
//! answer bit-identically to the single-worker reference under
//! every worker count × fault plan, with the ISSUE 7 conservation
//! identity and exactly-once replies intact.
//!
//! The tests inject synthetic [`InferenceEngine`]s so the pipeline
//! runs without PJRT artifacts; `sim_profile` is pinned so startup
//! skips the codec profiling pass.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use fmc_accel::compress::bitstream::FmapBitstream;
use fmc_accel::config::{models, AccelConfig};
use fmc_accel::coordinator::transport::{
    in_flight_profiles, new_in_flight, DenseTransport,
    InFlightMeasures, InterlayerTransport, SealedTransport,
    StagedEngine,
};
use fmc_accel::testutil::stages::{LogitStage, SmoothStage};
use fmc_accel::coordinator::{
    BatchPolicy, EngineFactory, FaultPlan, InferenceEngine,
    InferenceServer, InterlayerCache, Metrics, ServerConfig,
    ShedReason, SubmitError,
};
use fmc_accel::exec::ExecPool;
use fmc_accel::nn::Tensor3;
use fmc_accel::obs::{
    chrome_trace, TelemetrySnapshot, SEAM_KEYS, SEAM_NAMES,
};
use fmc_accel::sim::scheduler::{self, CompressionProfile};
use fmc_accel::sim::Accelerator;
use fmc_accel::store::{TieredStore, TieredStoreConfig};
use fmc_accel::util::json::Json;

/// Deterministic synthetic engine: class = (first pixel) mod 7, and
/// the first logit echoes the pixel so clients can verify routing.
/// Per-engine counters let the tests check the merged metrics against
/// per-worker sums.
struct TagEngine {
    cap: usize,
    images: Arc<AtomicUsize>,
    batches: Arc<AtomicUsize>,
}

impl InferenceEngine for TagEngine {
    fn max_batch(&self) -> usize {
        self.cap
    }

    fn infer(&mut self, images: &[Tensor3])
             -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(images.len(), Ordering::Relaxed);
        Ok(images
            .iter()
            .map(|im| {
                let tag = im.data[0] as usize;
                (tag % 7, vec![tag as f32])
            })
            .collect())
    }
}

fn tagged_image(tag: usize) -> Tensor3 {
    let mut t = Tensor3::zeros(1, 2, 2);
    t.data[0] = tag as f32; // exact for tag < 2^24
    t
}

fn stress_config(workers: usize) -> ServerConfig {
    let mut cfg =
        ServerConfig::new("/nonexistent-artifacts-not-used")
            .with_workers(workers);
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(2),
    };
    // Pin the hardware-accounting profile so startup skips the codec
    // profiling measurement (not under test here).
    cfg.sim_profile = Some(CompressionProfile::uncompressed());
    cfg
}

#[test]
fn eight_submitters_three_workers_lose_nothing() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 40;
    const WORKERS: usize = 3;

    let counters: Vec<(Arc<AtomicUsize>, Arc<AtomicUsize>)> = (0
        ..WORKERS)
        .map(|_| {
            (
                Arc::new(AtomicUsize::new(0)),
                Arc::new(AtomicUsize::new(0)),
            )
        })
        .collect();
    let factory_counters = counters.clone();
    let factory: EngineFactory = Arc::new(move |wi: usize| {
        let (images, batches) = factory_counters[wi].clone();
        Ok(Box::new(TagEngine {
            cap: 4,
            images,
            batches,
        }) as Box<dyn InferenceEngine>)
    });

    let server = InferenceServer::start_with_engines(
        stress_config(WORKERS),
        factory,
    )
    .expect("server start");

    std::thread::scope(|s| {
        for client in 0..CLIENTS {
            let server = &server;
            s.spawn(move || {
                let base = client * PER_CLIENT;
                let rxs: Vec<_> = (0..PER_CLIENT)
                    .map(|i| {
                        server
                            .submit(tagged_image(base + i))
                            .expect("submit while running")
                    })
                    .collect();
                for (i, rx) in rxs.into_iter().enumerate() {
                    let tag = base + i;
                    let resp = rx
                        .recv_timeout(Duration::from_secs(30))
                        .expect("response within 30s")
                        .expect("request served, not shed");
                    assert_eq!(resp.class, tag % 7, "class for {tag}");
                    assert_eq!(
                        resp.logits[0], tag as f32,
                        "logit echo for {tag}"
                    );
                }
            });
        }
    });

    let metrics = server.shutdown();
    let total = CLIENTS * PER_CLIENT;
    let worker_images: usize = counters
        .iter()
        .map(|(im, _)| im.load(Ordering::Relaxed))
        .sum();
    let worker_batches: usize = counters
        .iter()
        .map(|(_, b)| b.load(Ordering::Relaxed))
        .sum();

    assert_eq!(metrics.requests, total as u64, "no lost requests");
    assert_eq!(metrics.errors, 0);
    // Merged shutdown metrics must equal the per-worker sums.
    assert_eq!(worker_images, total);
    assert_eq!(metrics.batches, worker_batches as u64);
    // max_batch = 4 bounds the batch count from below.
    assert!(
        metrics.batches >= (total / 4) as u64,
        "batches {} < {}",
        metrics.batches,
        total / 4
    );
    // Work-stealing shards: the round-robin push spreads load, but a
    // fast sibling may legally steal a shard dry before its owner
    // wakes — so "every worker saw work" is no longer an invariant.
    // What must hold: the per-worker counts sum to the total (checked
    // above) and at least one engine actually ran.
    assert!(
        counters
            .iter()
            .any(|(im, _)| im.load(Ordering::Relaxed) > 0),
        "no worker ran a batch"
    );
}

/// One run of the post-idle burst scenario; returns the merged batch
/// count for 4 requests submitted back-to-back during an idle window.
fn post_idle_burst_batches() -> u64 {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let mut cfg = stress_config(1);
    // A linger long enough that a back-to-back burst normally lands
    // well inside it.
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(200),
    };
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    // Let the batcher pass through at least one idle poll window
    // (IDLE_POLL is 200ms).
    std::thread::sleep(Duration::from_millis(500));
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 4);
    metrics.batches
}

#[test]
fn idle_arrivals_still_coalesce() {
    // Satellite regression: the seed's idle fallback handled the
    // first post-idle request with a raw `recv` outside the batching
    // policy, producing a singleton batch — so a post-idle burst of 4
    // could NEVER land in one batch. The fixed dispatch loop routes
    // it back through poll_batch, so the burst normally coalesces
    // into exactly one policy-shaped batch. A bounded retry absorbs
    // the rare CI case where the client thread is descheduled past
    // the 200ms linger mid-burst.
    for attempt in 0..3 {
        if post_idle_burst_batches() == 1 {
            return;
        }
        eprintln!("attempt {attempt}: burst split by scheduling");
    }
    panic!(
        "post-idle bursts never coalesced into one batch in 3 runs"
    );
}

/// One server run with measured (sealed-stream) hardware accounting
/// through a shared interlayer bitstream cache, under the given
/// interlayer transport; returns the response payloads relevant to
/// accounting plus the shutdown metrics.
fn run_accounted_server(
    cache: Arc<Mutex<TieredStore>>,
    transport: Arc<dyn InterlayerTransport>,
) -> (Vec<(usize, u64, f64)>, Metrics) {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let mut cfg =
        ServerConfig::new("/nonexistent-artifacts-not-used")
            .with_workers(1)
            .with_cache(cache)
            .with_transport(transport);
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(2),
    };
    cfg.compressed = true;
    cfg.sim_profile = None; // measure through the sealed streams
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("accounted response")
                .expect("request served, not shed");
            (r.class, r.sim_cycles, r.sim_energy_j)
        })
        .collect();
    (resps, server.shutdown())
}

#[test]
fn cache_hit_responses_equal_cache_miss_responses() {
    // Satellite: the interlayer bitstream cache must be semantically
    // invisible — a server whose profiling pass *hits* the cache
    // (sealed streams reused, no recompression) answers with exactly
    // the same classes and simulated-hardware accounting as the
    // server that sealed everything from scratch.
    let cache = Arc::new(Mutex::new(TieredStore::ram_only(
        64 * 1024 * 1024,
    )));
    let (miss_resps, miss_metrics) =
        run_accounted_server(cache.clone(), Arc::new(SealedTransport));
    let after_miss = cache.lock().unwrap().cache_stats();
    assert!(after_miss.misses > 0, "first run must seal streams");
    assert_eq!(after_miss.hits, 0);
    assert!(after_miss.bytes_held > 0, "streams retained");
    assert!(miss_metrics.cache_misses > 0);
    assert_eq!(miss_metrics.cache_hits, 0);

    let (hit_resps, hit_metrics) =
        run_accounted_server(cache.clone(), Arc::new(SealedTransport));
    let after_hit = cache.lock().unwrap().cache_stats();
    assert_eq!(
        after_hit.misses, after_miss.misses,
        "hit path must not reseal"
    );
    assert!(hit_metrics.cache_hits > 0);
    assert_eq!(hit_metrics.cache_misses, 0);
    assert_eq!(
        miss_resps, hit_resps,
        "cache-hit responses must equal cache-miss responses"
    );
}

#[test]
fn sealed_hit_batches_equal_dense_miss_batches() {
    // Satellite (batch-level equivalence across *both* axes at once):
    // a dense-transport server on a cold cache (every profile sealed
    // fresh, dense batcher→worker currency) must answer exactly like
    // a sealed-transport server on the warm cache (profiles from
    // cached streams, sealed currency end to end).
    let cache = Arc::new(Mutex::new(TieredStore::ram_only(
        64 * 1024 * 1024,
    )));
    let (dense_miss, m1) =
        run_accounted_server(cache.clone(), Arc::new(DenseTransport));
    assert!(m1.cache_misses > 0, "cold cache must seal");
    assert_eq!(
        m1.sealed_shipments, 0,
        "dense transport ships no sealed envelopes"
    );
    let (sealed_hit, m2) =
        run_accounted_server(cache.clone(), Arc::new(SealedTransport));
    assert!(m2.cache_hits > 0, "warm cache must hit");
    assert_eq!(m2.cache_misses, 0, "no re-seal in the hot path");
    assert_eq!(m2.sealed_shipments, 4, "one sealed envelope per request");
    assert!(m2.sealed_stream_bytes > 0);
    assert_eq!(
        dense_miss, sealed_hit,
        "sealed-hit batches must equal dense-miss batches"
    );
}

/// Drive a server whose workers can never start: submits must begin
/// failing once the batcher exits (the seed's `let _ = tx.send(..)`
/// accepted requests into the void forever), and any request that did
/// get queued first must error out, not hang. Returns the shutdown
/// metrics for failure-accounting assertions.
fn drive_dead_server(server: InferenceServer) -> u64 {
    let deadline =
        std::time::Instant::now() + Duration::from_secs(30);
    let mut queued = Vec::new();
    loop {
        match server.submit(tagged_image(0)) {
            // The batcher exited: the dead server must say so, typed.
            Err(SubmitError::ShuttingDown) => break,
            Err(e) => panic!("dead server shed wrongly: {e}"),
            Ok(rx) => {
                queued.push(rx);
                assert!(
                    std::time::Instant::now() < deadline,
                    "submit kept succeeding with no live workers"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    // Requests the dying batcher drained get a typed ShuttingDown
    // reply; a submit racing the final drain may instead see its
    // channel close (the documented narrow window,
    // docs/robustness.md). What can never happen is a served reply
    // or a hang.
    for rx in queued {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Err(rej)) => {
                assert_eq!(rej.reason, ShedReason::ShuttingDown)
            }
            Err(_) => {}
            Ok(Ok(_)) => {
                panic!("dead server served a request")
            }
        }
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 0);
    metrics.errors
}

#[test]
fn no_live_workers_makes_submit_fail_fast() {
    // Every engine construction fails cleanly: both workers report
    // their error, the batcher exits, submit starts erroring.
    let factory: EngineFactory = Arc::new(|wi: usize| {
        anyhow::bail!("engine {wi} unavailable")
    });
    let server = InferenceServer::start_with_engines(
        stress_config(2),
        factory,
    )
    .unwrap();
    let errors = drive_dead_server(server);
    assert_eq!(errors, 2, "one error per failed worker");
}

#[test]
fn panicking_engine_factory_is_contained() {
    // The factory panics on the worker thread; the batcher counts the
    // startup death and exits, and submit surfaces the dead server.
    let factory: EngineFactory = Arc::new(|_: usize| {
        panic!("engine construction panic (test)")
    });
    let server = InferenceServer::start_with_engines(
        stress_config(1),
        factory,
    )
    .unwrap();
    let errors = drive_dead_server(server);
    assert_eq!(errors, 1, "one error for the dead worker");
}

// --- compressed-domain transport (ISSUE 5 tentpole) -------------------

/// Run `n` tagged requests through a TagEngine server under the given
/// transport and worker count; returns every response field a client
/// can observe.
fn run_transport_server(
    workers: usize, transport: Arc<dyn InterlayerTransport>, n: usize,
) -> (Vec<(usize, Vec<f32>, u64)>, Metrics) {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let cfg = stress_config(workers).with_transport(transport);
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("transport response")
                .expect("request served, not shed");
            (r.class, r.logits, r.sim_cycles)
        })
        .collect();
    let metrics = server.shutdown();
    assert_eq!(metrics.errors, 0);
    (resps, metrics)
}

#[test]
fn sealed_transport_bit_identical_to_dense_for_every_worker_count() {
    // ISSUE 5 acceptance: serving a batch through the sealed-
    // transport path returns bit-identical responses to the dense
    // path for every worker count. (Shard-count/pool-size invariance
    // of the seal/open primitives underneath is property-tested in
    // codec_par.rs — the worker's open-on-demand uses exactly those.)
    for workers in [1usize, 2, 3] {
        let (dense, dm) = run_transport_server(
            workers,
            Arc::new(DenseTransport),
            24,
        );
        let (sealed, sm) = run_transport_server(
            workers,
            Arc::new(SealedTransport),
            24,
        );
        assert_eq!(
            dense, sealed,
            "sealed transport changed bits at {workers} workers"
        );
        assert_eq!(dm.sealed_shipments, 0);
        assert_eq!(
            sm.sealed_shipments, 24,
            "every request must cross the seam sealed"
        );
        assert!(sm.sealed_stream_bytes > 0);
    }
}

/// Serve `n` requests through a 2-worker staged-engine server built
/// from the shared deterministic toy stages
/// (`testutil::stages::{SmoothStage, LogitStage}` — the same pipeline
/// the transport unit tests exercise, so the unit-level and
/// server-level sealed-equals-dense claims cover one pipeline); the
/// two workers share one in-flight measure block (integer
/// accumulators, so the merged measurement is scheduling-order
/// independent).
fn run_staged_server(
    transport: Arc<dyn InterlayerTransport>, n: usize,
) -> (Vec<(usize, Vec<f32>)>, InFlightMeasures) {
    let measures = new_in_flight(2);
    let m = Arc::clone(&measures);
    let t = Arc::clone(&transport);
    let factory: EngineFactory = Arc::new(move |_: usize| {
        Ok(Box::new(StagedEngine::new(
            vec![Box::new(SmoothStage), Box::new(LogitStage)],
            Arc::clone(&t),
            Arc::clone(&m),
            4,
        )) as Box<dyn InferenceEngine>)
    });
    let cfg = stress_config(2).with_transport(transport);
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("staged response")
                .expect("request served, not shed");
            (r.class, r.logits)
        })
        .collect();
    let metrics = server.shutdown();
    assert_eq!(metrics.errors, 0);
    (resps, measures)
}

#[test]
fn staged_workers_ship_sealed_interlayer_maps_bit_identically() {
    // Tentpole: workers shipping sealed outputs *between engine
    // stages* must not perturb a single response bit relative to the
    // dense reference, at batch level through the whole server.
    let (dense, _) = run_staged_server(Arc::new(DenseTransport), 12);
    let (sealed, measures) =
        run_staged_server(Arc::new(SealedTransport), 12);
    assert_eq!(dense, sealed, "staged sealed hand-off changed bits");
    let m = measures.lock().unwrap();
    let s0 = m[0].expect("stage 0 sealed its output");
    assert_eq!(s0.maps, 12, "one interlayer map per request");
    assert!(s0.data_bytes > 0 && s0.index_bytes > 0);
    assert!(m[1].is_none(), "the logit stage ships no fmap");
}

#[test]
fn in_flight_measures_drive_the_scheduler_without_reseal() {
    // Tentpole: the per-stage `StreamMeasure`s recorded off the
    // streams the pipeline *actually shipped* convert straight into
    // scheduler profiles — no second seal anywhere — and the sim's
    // wire-measured accounting fraction reaches 1.0 for profiled
    // layers (ISSUE 5 acceptance).
    let (_, measures) =
        run_staged_server(Arc::new(SealedTransport), 8);
    let profs = in_flight_profiles(&measures);
    let p0 = profs[0].expect("in-flight profile for stage 0");
    let stream = p0.stream.expect("real measured stream");
    assert!(stream.data_bytes > 0 && stream.index_bytes > 0);

    // Feed the in-flight profile to the scheduler over a real
    // network geometry: every plan must consume the measured bytes.
    let net = models::vgg16_bn();
    let profiles: Vec<Option<CompressionProfile>> =
        net.layers.iter().map(|_| Some(p0)).collect();
    let cfg = AccelConfig::default();
    let (plans, _) = scheduler::lower(&cfg, &net, &profiles);
    for plan in &plans {
        assert!(plan.out_profiled && plan.out_measured);
        assert_eq!(
            plan.out_stored_bytes,
            stream.data_bytes + stream.index_bytes
        );
    }
    let rep = Accelerator::new(cfg).run(&net, &profiles);
    assert!(rep.stats.fmap_wire_bits > 0, "wire bits booked");
    assert_eq!(
        rep.dma.measured_fraction(),
        1.0,
        "profiled traffic must be fully wire-measured, no re-seal"
    );
}

// --- InterlayerCache under concurrent workers (satellite) -------------

/// A stream with `n` value bytes in lane 0 (`stream_bytes` = n).
fn stream_of(n: usize) -> FmapBitstream {
    let mut bs = FmapBitstream::empty();
    bs.lanes[0] = vec![0u8; n];
    bs
}

#[test]
fn interlayer_cache_byte_accounting_survives_eviction_races() {
    // 8 worker threads hammer one shared cache with overlapping keys
    // under a budget small enough to force continuous eviction. The
    // lock serializes individual operations but not their
    // interleaving — the byte counter must equal the recounted entry
    // sum at the end, the budget must hold, and the hit/miss totals
    // must account for every lookup.
    const THREADS: usize = 8;
    const OPS: usize = 300;
    let cache = Arc::new(Mutex::new(InterlayerCache::new(2048)));
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let cache = Arc::clone(&cache);
            s.spawn(move || {
                for i in 0..OPS {
                    let key = format!("layer{}", (t * 7 + i * 13) % 23);
                    // the server's pattern: lookup under the lock,
                    // seal outside it, insert the sealed stream
                    let hit = cache.lock().unwrap().get(&key);
                    match hit {
                        Some(bs) => {
                            assert!(bs.stream_bytes() > 0);
                        }
                        None => {
                            let bs =
                                stream_of(64 + (i * 31) % 200);
                            cache
                                .lock()
                                .unwrap()
                                .insert_arc(key, Arc::new(bs));
                        }
                    }
                }
            });
        }
    });
    let c = cache.lock().unwrap();
    let stats = c.stats();
    assert_eq!(
        c.bytes_held(),
        c.recounted_bytes(),
        "byte counter drifted from the entries"
    );
    assert!(c.bytes_held() <= 2048, "budget violated");
    assert_eq!(
        stats.hits + stats.misses,
        (THREADS * OPS) as u64,
        "every lookup accounted"
    );
    assert!(stats.evictions > 0, "budget pressure must evict");
}

// --- tiered sealed-stream store under the server (ISSUE 10) -----------

/// Fresh scratch directory for a disk-backed store, named so
/// `make test-store`'s `/tmp/fmc-store-*` hygiene globs cover it.
fn store_scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fmc-store-{}-stress-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn store_disk_hits_answer_bit_identical_to_ram_and_cold() {
    // Tentpole acceptance (tri-identity): the same server run served
    // three ways — cold (every stream sealed fresh), warm RAM (every
    // profile from the RAM tier), and disk (the whole RAM tier
    // demoted to the page file first) — must produce byte-identical
    // responses: class, sim_cycles, and sim_energy_j all equal.
    let dir = store_scratch("tri");
    let mut cfg = TieredStoreConfig::new(&dir, 64 * 1024 * 1024);
    cfg.page_size_bytes = 1 << 20; // every record must fit one page
    let store = Arc::new(Mutex::new(
        TieredStore::open(cfg).expect("open store"),
    ));

    let (cold, m_cold) =
        run_accounted_server(store.clone(), Arc::new(SealedTransport));
    assert!(m_cold.cache_misses > 0, "cold run must seal streams");
    assert_eq!(m_cold.cache_hits, 0);

    let (ram, m_ram) =
        run_accounted_server(store.clone(), Arc::new(SealedTransport));
    assert!(m_ram.cache_hits > 0, "warm run must hit the RAM tier");
    assert_eq!(m_ram.cache_misses, 0);
    {
        let s = store.lock().unwrap();
        let st = s.stats();
        assert!(st.ram_hits > 0, "warm run's hits are RAM hits");
        assert_eq!(st.disk_hits, 0, "nothing demoted yet");
    }

    // Force the disk tier: demote every cached stream to the page
    // file, then serve again — the hits must come back from disk.
    {
        let mut s = store.lock().unwrap();
        s.demote_all();
        assert_eq!(s.bytes_held(), 0, "RAM tier fully demoted");
        let st = s.stats();
        assert_eq!(st.spill_failures, 0, "every demotion must land");
        assert_eq!(st.pending_spills, 0, "demote_all flushes");
        assert!(st.pages_written > 0, "demotion must write pages");
        assert!(st.disk_entries > 0, "demotion must index entries");
    }
    let (disk, m_disk) =
        run_accounted_server(store.clone(), Arc::new(SealedTransport));
    assert!(m_disk.cache_hits > 0, "disk hits still count as hits");
    assert_eq!(m_disk.cache_misses, 0, "disk run must not re-seal");
    {
        let s = store.lock().unwrap();
        let st = s.stats();
        assert!(st.disk_hits > 0, "third run must hit the disk tier");
        assert_eq!(
            st.ram_hits + st.disk_hits + st.misses,
            st.lookups,
            "tier-hit conservation"
        );
    }

    assert_eq!(cold, ram, "RAM hits drifted from the cold re-seal");
    assert_eq!(ram, disk, "disk hits drifted from RAM hits");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_spill_race_keeps_exact_byte_accounting() {
    // 8 worker threads hammer one shared disk-backed store with
    // overlapping keys under a RAM budget small enough to force
    // continuous eviction — every eviction now *spills* instead of
    // dropping, and lookups race promotions racing drains. The byte
    // counter must equal the recounted entry sum, the budget must
    // hold, and the tier-hit conservation identity must account for
    // every lookup with zero spill failures.
    const THREADS: usize = 8;
    const OPS: usize = 300;
    let dir = store_scratch("race");
    let mut cfg = TieredStoreConfig::new(&dir, 2048);
    cfg.page_size_bytes = 4096;
    let store = Arc::new(Mutex::new(
        TieredStore::open(cfg).expect("open store"),
    ));
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let store = Arc::clone(&store);
            s.spawn(move || {
                for i in 0..OPS {
                    let key = format!("layer{}", (t * 7 + i * 13) % 23);
                    // the server's pattern: lookup under the lock,
                    // seal outside it, insert the sealed stream
                    let hit = store.lock().unwrap().get(&key);
                    match hit {
                        Some(bs) => {
                            assert!(bs.stream_bytes() > 0);
                        }
                        None => {
                            let bs =
                                stream_of(64 + (i * 31) % 200);
                            store
                                .lock()
                                .unwrap()
                                .insert_arc(key, Arc::new(bs));
                        }
                    }
                }
            });
        }
    });
    let mut s = store.lock().unwrap();
    s.flush();
    let stats = s.stats();
    assert_eq!(
        s.bytes_held(),
        s.recounted_bytes(),
        "byte counter drifted from the entries"
    );
    assert!(s.bytes_held() <= 2048, "budget violated");
    assert_eq!(
        stats.lookups,
        (THREADS * OPS) as u64,
        "every get is exactly one lookup"
    );
    assert_eq!(
        stats.ram_hits + stats.disk_hits + stats.misses,
        stats.lookups,
        "tier-hit conservation under races"
    );
    assert!(stats.spills > 0, "budget pressure must spill");
    assert!(stats.disk_hits > 0, "spilled keys must serve from disk");
    assert_eq!(stats.spill_failures, 0, "no spill may be lost");
    assert_eq!(stats.pending_spills, 0, "flush drains the queue");
    assert!(stats.pages_written > 0, "churn must commit pages");
    drop(s);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- pipeline telemetry (ISSUE 6) -------------------------------------

/// TagEngine server serving `n` requests at the given worker count;
/// returns the full telemetry snapshot (optionally with a small span
/// ring to force overflow).
fn run_telemetry_server(
    workers: usize, n: usize, ring_cap: Option<usize>,
) -> TelemetrySnapshot {
    let factory: EngineFactory = Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    });
    let mut cfg = stress_config(workers);
    if let Some(cap) = ring_cap {
        cfg = cfg.with_span_ring_cap(cap);
    }
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    for rx in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("telemetry response")
            .expect("request served, not shed");
        // The response carries its span, already closed at reply.
        assert!(resp.span.is_complete(), "response span incomplete");
    }
    server.shutdown_telemetry()
}

fn num(j: &Json) -> f64 {
    match j {
        Json::Num(n) => *n,
        other => panic!("expected number, got {other}"),
    }
}

#[test]
fn telemetry_spans_cover_every_request() {
    for workers in [1usize, 3] {
        let snap = run_telemetry_server(workers, 20, None);
        assert_eq!(snap.metrics.requests, 20);
        assert_eq!(snap.spans_recorded(), 20, "{workers} workers");
        assert_eq!(snap.spans_dropped(), 0);
        assert_eq!(snap.workers, workers);
        for ring in &snap.spans {
            for span in ring.iter() {
                assert!(span.is_complete(), "span {} gapped", span.seq);
                assert!((span.worker as usize) < workers);
                // The five seams exactly partition end to end.
                let seam_sum: u64 = (0..SEAM_KEYS.len())
                    .map(|i| span.seam_us(i).unwrap())
                    .sum();
                assert_eq!(seam_sum, span.total_us().unwrap());
            }
        }
        // Same partition identity, aggregated: per-stage histogram
        // mass equals (so in particular never exceeds) the
        // end-to-end mass.
        let m = &snap.metrics;
        let stage_mass: u64 = (0..SEAM_KEYS.len())
            .map(|i| m.stage_hist(i).sum_us())
            .sum();
        assert_eq!(stage_mass, m.latency_hist().sum_us());
        assert_eq!(m.latency_hist().count(), 20);
    }
}

#[test]
fn span_ring_overflow_keeps_exact_accounting() {
    // A 4-slot ring under 20 requests must evict — but the counters
    // stay exact and the histograms still see every request.
    let snap = run_telemetry_server(1, 20, Some(4));
    assert_eq!(snap.metrics.requests, 20);
    assert_eq!(snap.spans_recorded(), 20);
    assert!(snap.spans_buffered() <= 4);
    assert!(snap.spans_dropped() >= 16);
    assert_eq!(
        snap.spans_recorded() - snap.spans_dropped(),
        snap.spans_buffered() as u64,
        "recorded - dropped must equal what is still buffered"
    );
    assert_eq!(snap.metrics.latency_hist().count(), 20);
}

#[test]
fn chrome_trace_export_covers_every_request_and_seam() {
    const N: usize = 24;
    const WORKERS: usize = 3;
    let snap = run_telemetry_server(WORKERS, N, None);
    // Round-trip through the parser: the export must be valid JSON.
    let doc = Json::parse(&chrome_trace(&snap.spans).to_string())
        .expect("trace JSON parses");
    let Json::Arr(events) = doc.get("traceEvents") else {
        panic!("traceEvents missing");
    };
    let slices: Vec<&Json> = events
        .iter()
        .filter(|e| matches!(e.get("ph"), Json::Str(s) if s == "X"))
        .collect();
    assert_eq!(
        slices.len(),
        N * SEAM_KEYS.len(),
        "one slice per request per seam"
    );
    let mut pids = std::collections::BTreeSet::new();
    for s in &slices {
        let pid = num(s.get("pid")) as usize;
        assert!(pid < WORKERS, "pid {pid} out of range");
        pids.insert(pid);
        assert!(num(s.get("dur")) >= 0.0);
    }
    // Every worker that emitted slices has a process_name record.
    let named: std::collections::BTreeSet<usize> = events
        .iter()
        .filter(|e| {
            matches!(e.get("ph"), Json::Str(s) if s == "M")
                && matches!(e.get("name"),
                            Json::Str(s) if s == "process_name")
        })
        .map(|e| num(e.get("pid")) as usize)
        .collect();
    assert!(pids.is_subset(&named), "unnamed worker pid in trace");
    // One request's slices, in time order, walk the seams in
    // pipeline order (sort is stable, so equal timestamps keep the
    // export's per-span emission order).
    let min_seq = slices
        .iter()
        .map(|s| num(s.get("args").get("seq")) as u64)
        .min()
        .unwrap();
    let mut first: Vec<&Json> = slices
        .iter()
        .copied()
        .filter(|s| num(s.get("args").get("seq")) as u64 == min_seq)
        .collect();
    first.sort_by_key(|s| num(s.get("ts")) as u64);
    let names: Vec<&str> = first
        .iter()
        .map(|s| match s.get("name") {
            Json::Str(n) => n.as_str(),
            other => panic!("slice name not a string: {other}"),
        })
        .collect();
    assert_eq!(names, SEAM_NAMES, "seam slices out of order");
}

#[test]
fn stats_json_shape_matches_schema() {
    let snap = run_telemetry_server(2, 16, None);
    let doc = Json::parse(&snap.to_json().to_string())
        .expect("stats JSON parses");
    for key in [
        "schema", "workers", "transport", "requests", "batches",
        "errors", "latency_us", "pool", "spans",
    ] {
        assert!(
            !matches!(doc.get(key), Json::Null),
            "top-level key {key} missing"
        );
    }
    let e2e = doc.get("latency_us").get("end_to_end");
    let hist_keys = [
        "count", "sum_us", "max_us", "mean_us", "p50_us", "p95_us",
        "p99_us", "p999_us",
    ];
    for hk in hist_keys {
        assert!(
            !matches!(e2e.get(hk), Json::Null),
            "end_to_end histogram key {hk} missing"
        );
    }
    // What tools/bench_compare.py --check-stats gates, asserted at
    // the source: every stage histogram present and the stage
    // latency mass bounded by the end-to-end mass.
    let stages = doc.get("latency_us").get("stages");
    let mut stage_mass = 0.0;
    for sk in SEAM_KEYS {
        let h = stages.get(sk);
        for hk in hist_keys {
            assert!(
                !matches!(h.get(hk), Json::Null),
                "stage {sk} histogram key {hk} missing"
            );
        }
        stage_mass += num(h.get("sum_us"));
    }
    assert!(stage_mass <= num(e2e.get("sum_us")));
    assert_eq!(num(doc.get("requests")), 16.0);
    assert_eq!(num(doc.get("spans").get("recorded")), 16.0);
    let pool = doc.get("pool");
    assert_eq!(
        num(pool.get("jobs_submitted")),
        num(pool.get("jobs_executed")),
        "pool job accounting must balance in the snapshot"
    );
    // Schema 4 (ISSUE 10): the tiered-store block (and, from schema
    // 3, the sharded-queue block plus p999 on every histogram,
    // asserted via hist_keys above).
    assert_eq!(num(doc.get("schema")), 4.0);
    let store = doc.get("store");
    for key in [
        "lookups", "ram_hits", "disk_hits", "misses", "spills",
        "spilled_bytes", "spill_failures", "page_faults",
        "pages_written", "pages_rejected", "disk_entries",
        "pending_spills",
    ] {
        assert!(
            !matches!(store.get(key), Json::Null),
            "store key {key} missing"
        );
        assert!(num(store.get(key)) >= 0.0, "store key {key} negative");
    }
    // Tier-hit conservation in the exported JSON — degenerate here
    // (pinned sim_profile means the store saw no lookups), but the
    // identity and the block's shape are what --check-stats gates.
    assert_eq!(
        num(store.get("ram_hits"))
            + num(store.get("disk_hits"))
            + num(store.get("misses")),
        num(store.get("lookups")),
        "tier-hit conservation in the exported JSON"
    );
    let queue = doc.get("queue");
    for key in [
        "shards", "pulls", "steals", "stolen_requests",
        "shard_depth_highwater",
    ] {
        assert!(
            !matches!(queue.get(key), Json::Null),
            "queue key {key} missing"
        );
        assert!(num(queue.get(key)) >= 0.0);
    }
    assert_eq!(num(queue.get("shards")), 2.0, "one shard per worker");
    // Quantiles must be monotone within each histogram.
    for h in [
        e2e,
        doc.get("latency_us").get("stages").get("enqueue_to_batch"),
    ] {
        let p50 = num(h.get("p50_us"));
        let p95 = num(h.get("p95_us"));
        let p99 = num(h.get("p99_us"));
        let p999 = num(h.get("p999_us"));
        let max = num(h.get("max_us"));
        assert!(
            p50 <= p95 && p95 <= p99 && p99 <= p999 && p999 <= max,
            "quantiles not monotone: {p50} {p95} {p99} {p999} {max}"
        );
    }
    // Admission block (ISSUE 7), still gated by --check-stats.
    let adm = doc.get("admission");
    let shed_keys = [
        "shed_queue_full", "shed_deadline_submit",
        "shed_deadline_batch", "shed_deadline_open", "shed_shutdown",
    ];
    for key in ["queue_cap", "submitted", "replied", "failed",
                "requeued_batches", "requeued_requests",
                "open_retries"]
        .into_iter()
        .chain(shed_keys)
    {
        assert!(
            !matches!(adm.get(key), Json::Null),
            "admission key {key} missing"
        );
    }
    let shed: f64 =
        shed_keys.iter().map(|k| num(adm.get(k))).sum();
    assert_eq!(
        num(adm.get("submitted")),
        num(adm.get("replied")) + shed + num(adm.get("failed")),
        "conservation identity in the exported JSON"
    );
    assert_eq!(num(adm.get("replied")), num(doc.get("requests")));
}

// --- bounded admission, deadlines, fault injection (ISSUE 7) ----------

/// TagEngine behind a shared gate: `infer` blocks until the test
/// drops its lock on the gate, so a test can hold the whole pipeline
/// full at a known point — the only way to drive the bounded
/// admission queue to a deterministic `QueueFull`, or to age queued
/// requests past their deadlines.
struct GateEngine {
    inner: TagEngine,
    gate: Arc<Mutex<()>>,
}

impl InferenceEngine for GateEngine {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn infer(&mut self, images: &[Tensor3])
             -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        let _hold = self.gate.lock().unwrap();
        self.inner.infer(images)
    }
}

fn gated_factory(gate: Arc<Mutex<()>>) -> EngineFactory {
    Arc::new(move |_: usize| {
        Ok(Box::new(GateEngine {
            inner: TagEngine {
                cap: 4,
                images: Arc::new(AtomicUsize::new(0)),
                batches: Arc::new(AtomicUsize::new(0)),
            },
            gate: Arc::clone(&gate),
        }) as Box<dyn InferenceEngine>)
    })
}

fn tag_factory() -> EngineFactory {
    Arc::new(|_: usize| {
        Ok(Box::new(TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        }) as Box<dyn InferenceEngine>)
    })
}

#[test]
fn bounded_admission_sheds_queue_full_with_exact_accounting() {
    // Tentpole acceptance: with the engine gated shut and a 1-deep
    // queue, submits must start shedding typed QueueFull — and once
    // the gate opens, every *accepted* request is served, with
    // `submitted == replied + shed` holding exactly.
    let gate = Arc::new(Mutex::new(()));
    let factory = gated_factory(Arc::clone(&gate));
    let mut cfg = stress_config(1).with_queue_cap(1);
    cfg.policy = BatchPolicy {
        max_batch: 1,
        linger: Duration::from_millis(1),
    };
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();

    let hold = gate.lock().unwrap();
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    let deadline =
        std::time::Instant::now() + Duration::from_secs(30);
    let mut tag = 0usize;
    while shed < 3 {
        assert!(
            std::time::Instant::now() < deadline,
            "queue never filled (only {shed} sheds)"
        );
        match server.submit(tagged_image(tag)) {
            Ok(rx) => accepted.push((tag, rx)),
            Err(SubmitError::QueueFull { capacity }) => {
                assert_eq!(capacity, 1, "shed names the bound it hit");
                shed += 1;
            }
            Err(e) => panic!("unexpected shed: {e}"),
        }
        tag += 1;
        std::thread::sleep(Duration::from_millis(1));
    }
    drop(hold);

    let n_ok = accepted.len() as u64;
    for (tag, rx) in accepted {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("accepted request answered")
            .expect("accepted request served");
        assert_eq!(resp.class, tag % 7, "class for {tag}");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, n_ok, "every accepted request replied");
    assert_eq!(m.shed_queue_full, shed);
    assert_eq!(m.submitted, n_ok + shed);
    assert_eq!(m.accounted(), m.submitted, "conservation identity");
    assert_eq!(m.failed, 0);
    assert_eq!(m.errors, 0);
}

#[test]
fn zero_budget_submit_is_rejected_at_the_door() {
    let server = InferenceServer::start_with_engines(
        stress_config(1),
        tag_factory(),
    )
    .unwrap();
    let err = match server
        .submit_within(tagged_image(3), Duration::ZERO)
    {
        Err(e) => e,
        Ok(_) => panic!("zero budget must shed at the door"),
    };
    assert_eq!(err, SubmitError::DeadlinePassed);
    // A viable budget still serves.
    let rx = server
        .submit_within(tagged_image(3), Duration::from_secs(30))
        .expect("viable budget admits");
    let resp = rx
        .recv_timeout(Duration::from_secs(30))
        .unwrap()
        .expect("viable request served");
    assert_eq!(resp.class, 3);
    let m = server.shutdown();
    assert_eq!(m.submitted, 2);
    assert_eq!(m.shed_deadline_submit, 1);
    assert_eq!(m.requests, 1);
    assert_eq!(m.accounted(), m.submitted, "conservation identity");
}

#[test]
fn expired_requests_shed_at_batch_and_open_seams() {
    // Deadlines are enforced at seams, not mid-flight. With the
    // sharded front door both seams live on the pulling worker:
    // requests that expire while queued in a shard shed when the
    // worker pulls them (the batch seam), and a request that was
    // fresh at the pull but expires before its envelope opens sheds
    // at the open seam. An injected open delay ages the second kind
    // deterministically.
    let gate = Arc::new(Mutex::new(()));
    let factory = gated_factory(Arc::clone(&gate));
    let mut cfg = stress_config(1).with_faults(Arc::new(
        FaultPlan::new(1)
            .with_open_delay(0, Duration::from_millis(300)),
    ));
    cfg.policy = BatchPolicy {
        max_batch: 1,
        linger: Duration::from_millis(1),
    };
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();

    // Head request: generous budget, so it survives the open delay
    // and blocks inside the gated engine, keeping the worker busy.
    let hold = gate.lock().unwrap();
    let head = server
        .submit_within(tagged_image(0), Duration::from_secs(30))
        .expect("head admitted");
    // Queued requests: aged past their 200ms budget while the worker
    // is stuck, so the pull seam sheds every one.
    let queued: Vec<_> = (1..5)
        .map(|i| {
            server
                .submit_within(
                    tagged_image(i),
                    Duration::from_millis(200),
                )
                .expect("default queue holds 4")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(1000));
    drop(hold);

    let head_resp = head
        .recv_timeout(Duration::from_secs(30))
        .expect("head answered")
        .expect("head served despite the open delay");
    assert!(head_resp.span.is_complete());
    for rx in queued {
        let rej = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("typed answer, never a hang")
            .expect_err("aged request must shed");
        assert_eq!(
            rej.reason.key(),
            "deadline-batch",
            "shard-aged requests shed at the pull seam"
        );
    }
    // Open-seam shed: fresh at the pull, but the 300ms open delay
    // outlives a 150ms budget.
    let late = server
        .submit_within(tagged_image(9), Duration::from_millis(150))
        .expect("late request admitted");
    let rej = late
        .recv_timeout(Duration::from_secs(30))
        .expect("typed answer, never a hang")
        .expect_err("must shed at the open seam");
    assert_eq!(rej.reason.key(), "deadline-open");

    let m = server.shutdown();
    assert_eq!(m.requests, 1, "exactly the head request is served");
    assert_eq!(m.shed_deadline_batch, 4);
    assert_eq!(m.shed_deadline_open, 1);
    assert_eq!(m.submitted, 6);
    assert_eq!(m.accounted(), 6, "conservation identity");
    // Satellite regression at system level: shed requests leave NO
    // partial stage mass, so the seam histograms still exactly
    // partition the end-to-end mass of the served request.
    let stage_mass: u64 = (0..SEAM_KEYS.len())
        .map(|i| m.stage_hist(i).sum_us())
        .sum();
    assert_eq!(stage_mass, m.latency_hist().sum_us());
    assert_eq!(m.latency_hist().count(), m.requests);
}

#[test]
fn worker_death_requeues_in_flight_exactly_once() {
    const N: usize = 60;
    const WORKERS: usize = 3;
    let cfg = stress_config(WORKERS).with_faults(Arc::new(
        FaultPlan::new(WORKERS).with_worker_kill(1, 2),
    ));
    let server =
        InferenceServer::start_with_engines(cfg, tag_factory())
            .unwrap();
    let rxs: Vec<_> = (0..N)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    for (tag, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("reply despite the worker death")
            .expect("request served via requeue");
        // Bit-identity under faults: the replayed batches answer
        // exactly like the fault-free run would.
        assert_eq!(resp.class, tag % 7, "class for {tag}");
        assert_eq!(
            resp.logits[0], tag as f32,
            "logit echo for {tag}"
        );
        assert!(
            rx.try_recv().is_err(),
            "request {tag} answered more than once"
        );
    }
    let m = server.shutdown();
    assert_eq!(m.requests, N as u64, "every request replied");
    assert_eq!(m.submitted, N as u64);
    assert_eq!(m.failed, 0, "a single requeue absorbed the death");
    assert_eq!(m.errors, 1, "the kill is one infra event");
    assert!(
        m.requeued_batches >= 1,
        "the dead worker's in-flight batch replayed"
    );
    assert!(m.requeued_requests >= 1);
    assert_eq!(m.accounted(), m.submitted, "conservation identity");
}

/// `n` tagged requests through a 1-worker TagEngine server under the
/// given transport + fault plan; returns the client-visible payloads
/// and the shutdown metrics.
fn run_faulted_server(
    transport: Arc<dyn InterlayerTransport>, faults: Arc<FaultPlan>,
    n: usize,
) -> (Vec<(usize, Vec<f32>)>, Metrics) {
    let cfg = stress_config(1)
        .with_transport(transport)
        .with_faults(faults);
    let server =
        InferenceServer::start_with_engines(cfg, tag_factory())
            .unwrap();
    let rxs: Vec<_> = (0..n)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("faulted response")
                .expect("the open retry must recover");
            (r.class, r.logits)
        })
        .collect();
    (resps, server.shutdown())
}

#[test]
fn open_failures_recover_via_retry_and_stay_bit_identical() {
    // Every request's first envelope-open attempt fails (period 1);
    // the single retry must recover every one, under both transports,
    // without changing a response bit between them.
    let plan =
        || Arc::new(FaultPlan::new(1).with_open_fail_every(1, 0));
    let (sealed, sm) =
        run_faulted_server(Arc::new(SealedTransport), plan(), 16);
    let (dense, dm) =
        run_faulted_server(Arc::new(DenseTransport), plan(), 16);
    assert_eq!(sealed, dense, "open-retry changed response bits");
    for m in [&sm, &dm] {
        assert_eq!(
            m.open_retries, 16,
            "one injected retry per request"
        );
        assert_eq!(
            m.failed, 0,
            "transient open failures never fail a request"
        );
        assert_eq!(m.errors, 0);
        assert_eq!(m.requests, 16);
        assert_eq!(m.accounted(), m.submitted);
    }
}

#[test]
fn chaos_sweep_keeps_accounting_exact_and_replies_bit_identical() {
    // Seeded chaos across worker counts: one worker killed mid-run,
    // periodic open failures, a ship or open delay — every client
    // still gets exactly one reply, bit-identical to the fault-free
    // TagEngine answer, and the conservation identity stays exact.
    const N: usize = 40;
    for workers in [2usize, 4] {
        for seed in [1u64, 2, 3] {
            let cfg = stress_config(workers).with_faults(Arc::new(
                FaultPlan::seeded(seed, workers),
            ));
            let server = InferenceServer::start_with_engines(
                cfg,
                tag_factory(),
            )
            .unwrap();
            let rxs: Vec<_> = (0..N)
                .map(|i| server.submit(tagged_image(i)).unwrap())
                .collect();
            for (tag, rx) in rxs.into_iter().enumerate() {
                let resp = rx
                    .recv_timeout(Duration::from_secs(30))
                    .unwrap_or_else(|e| {
                        panic!(
                            "seed {seed}/{workers}w: reply for \
                             {tag} lost: {e}"
                        )
                    })
                    .unwrap_or_else(|r| {
                        panic!(
                            "seed {seed}/{workers}w: {tag} shed: {r}"
                        )
                    });
                assert_eq!(
                    resp.class,
                    tag % 7,
                    "seed {seed}/{workers}w: class drifted for {tag}"
                );
                assert_eq!(
                    resp.logits[0], tag as f32,
                    "seed {seed}/{workers}w: logits drifted for {tag}"
                );
                assert!(
                    rx.try_recv().is_err(),
                    "seed {seed}/{workers}w: {tag} answered twice"
                );
            }
            let m = server.shutdown();
            assert_eq!(m.requests, N as u64);
            assert_eq!(m.submitted, N as u64);
            assert_eq!(m.failed, 0);
            assert_eq!(
                m.accounted(),
                m.submitted,
                "seed {seed}/{workers}w: conservation identity"
            );
            assert_eq!(
                m.errors, 1,
                "seed {seed}/{workers}w: seeded plans kill exactly \
                 one worker"
            );
            assert!(
                m.requeued_batches >= 1,
                "seed {seed}/{workers}w: the kill must exercise \
                 the requeue path"
            );
        }
    }
}

/// One 2-worker accounted run — measured sealed-stream profiles via a
/// fresh cache, sealed transport — under an optional fault plan;
/// returns the hardware-accounting payloads and the full snapshot.
fn run_accounted_chaos(
    faults: Option<Arc<FaultPlan>>,
) -> (Vec<(usize, u64, f64)>, TelemetrySnapshot) {
    let mut cfg =
        ServerConfig::new("/nonexistent-artifacts-not-used")
            .with_workers(2)
            .with_cache(Arc::new(Mutex::new(TieredStore::ram_only(
                64 * 1024 * 1024,
            ))))
            .with_transport(Arc::new(SealedTransport));
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(2),
    };
    cfg.compressed = true;
    cfg.sim_profile = None; // measure through the sealed streams
    if let Some(f) = faults {
        cfg = cfg.with_faults(f);
    }
    let server =
        InferenceServer::start_with_engines(cfg, tag_factory())
            .unwrap();
    let rxs: Vec<_> = (0..8)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    let resps = rxs
        .into_iter()
        .map(|rx| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .expect("accounted chaos response")
                .expect("request served despite faults");
            (r.class, r.sim_cycles, r.sim_energy_j)
        })
        .collect();
    (resps, server.shutdown_telemetry())
}

#[test]
fn chaos_keeps_wire_measured_accounting_exact() {
    // A worker kill + transient open failures must not move a single
    // bit of the wire-measured hardware accounting, and the exported
    // snapshot must keep measured_fraction at 1.0 with the
    // conservation identity intact.
    let (clean, clean_snap) = run_accounted_chaos(None);
    let (faulted, snap) = run_accounted_chaos(Some(Arc::new(
        FaultPlan::new(2)
            .with_worker_kill(1, 1)
            .with_open_fail_every(2, 0),
    )));
    assert_eq!(clean, faulted, "faults changed accounting bits");
    for s in [&clean_snap, &snap] {
        let dma = s.dma.as_ref().expect("profiling pass ran");
        assert_eq!(
            dma.measured_fraction(),
            1.0,
            "profiled traffic fully wire-measured under faults"
        );
        assert_eq!(s.metrics.accounted(), s.metrics.submitted);
        assert_eq!(s.metrics.requests, 8);
        assert_eq!(s.metrics.failed, 0);
    }
    assert_eq!(snap.metrics.errors, 1, "the injected kill");
    assert!(snap.metrics.requeued_batches >= 1);
}

#[test]
fn conservation_identity_holds_under_churn() {
    // Property test (satellite): random mixes of deadline-free,
    // tight-deadline, and zero-budget submits against a gated, kill-
    // injected server across worker counts — every client-side
    // outcome tally must equal its server counter, and
    // `submitted == replied + shed_* + failed` must hold exactly.
    use std::collections::BTreeMap;
    const OPS: usize = 60;
    for (case, workers) in [1usize, 2, 4].into_iter().enumerate() {
        let gate = Arc::new(Mutex::new(()));
        let factory = gated_factory(Arc::clone(&gate));
        let mut cfg = stress_config(workers).with_queue_cap(4);
        cfg.policy = BatchPolicy {
            max_batch: 2,
            linger: Duration::from_millis(1),
        };
        let killed = workers >= 2;
        if killed {
            // Never kill a lone worker (the requeue needs a
            // survivor, same rule FaultPlan::seeded enforces).
            cfg = cfg.with_faults(Arc::new(
                FaultPlan::new(workers).with_worker_kill(0, 2),
            ));
        }
        let server =
            InferenceServer::start_with_engines(cfg, factory)
                .unwrap();
        let mut prng = fmc_accel::testutil::Prng::new(
            0xC0FFEE + case as u64,
        );
        let hold = gate.lock().unwrap();
        let mut pending = Vec::new();
        let mut client: BTreeMap<&'static str, u64> = BTreeMap::new();
        for i in 0..OPS {
            let sent = match prng.below(3) {
                0 => server.submit(tagged_image(i)),
                1 => server.submit_within(
                    tagged_image(i),
                    Duration::from_millis(40),
                ),
                _ => server.submit_within(
                    tagged_image(i),
                    Duration::ZERO,
                ),
            };
            match sent {
                Ok(rx) => pending.push((i, rx)),
                Err(SubmitError::QueueFull { .. }) => {
                    *client.entry("queue-full").or_default() += 1
                }
                Err(SubmitError::DeadlinePassed) => {
                    *client.entry("deadline-submit").or_default() += 1
                }
                Err(SubmitError::ShuttingDown) => {
                    *client.entry("shutdown-submit").or_default() += 1
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // Age the tight-deadline requests past expiry, then open.
        std::thread::sleep(Duration::from_millis(150));
        drop(hold);

        let mut ok = 0u64;
        let mut lost = 0u64;
        let mut replies: BTreeMap<&'static str, u64> =
            BTreeMap::new();
        for (tag, rx) in pending {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(resp)) => {
                    assert_eq!(
                        resp.class,
                        tag % 7,
                        "{workers}w: class for {tag}"
                    );
                    ok += 1;
                }
                Ok(Err(rej)) => {
                    *replies.entry(rej.reason.key()).or_default() += 1
                }
                Err(_) => lost += 1,
            }
        }
        let snap = server.shutdown_telemetry();
        let m = &snap.metrics;
        let r = |k: &str| replies.get(k).copied().unwrap_or(0);
        let c = |k: &str| client.get(k).copied().unwrap_or(0);
        assert_eq!(lost, 0, "{workers}w: replies lost");
        assert_eq!(m.submitted, OPS as u64);
        assert_eq!(m.requests, ok, "{workers}w: replied tally");
        assert_eq!(m.shed_queue_full, c("queue-full"));
        assert_eq!(m.shed_deadline_submit, c("deadline-submit"));
        assert_eq!(m.shed_deadline_batch, r("deadline-batch"));
        assert_eq!(m.shed_deadline_open, r("deadline-open"));
        assert_eq!(
            m.shed_shutdown,
            c("shutdown-submit") + r("shutting-down")
        );
        assert_eq!(
            m.failed,
            r("worker-lost") + r("open-failed") + r("engine-error")
        );
        assert_eq!(
            m.accounted(),
            m.submitted,
            "{workers}w: conservation identity"
        );
        assert_eq!(
            m.errors,
            u64::from(killed),
            "{workers}w: infra events"
        );
        assert_eq!(
            snap.spans_recorded(),
            ok,
            "{workers}w: one span per served request"
        );
    }
}

// --- sharded work-stealing front door (ISSUE 9) -----------------------

#[test]
fn sharded_door_matches_single_batcher_reference_under_churn() {
    // Tentpole acceptance: the sharded, work-stealing door must be
    // semantically invisible. A single worker on a single shard IS
    // the old single-batcher pipeline (degenerate sharding, nothing
    // to steal), so it serves as the reference; every worker count ×
    // seeded fault plan must answer bit-identically to it, request
    // for request, with the conservation identity intact.
    const N: usize = 48;
    let run = |workers: usize, faults: Option<Arc<FaultPlan>>| {
        let mut cfg = stress_config(workers);
        if let Some(f) = faults {
            cfg = cfg.with_faults(f);
        }
        let server =
            InferenceServer::start_with_engines(cfg, tag_factory())
                .unwrap();
        let rxs: Vec<_> = (0..N)
            .map(|i| server.submit(tagged_image(i)).unwrap())
            .collect();
        let resps: Vec<(usize, Vec<f32>)> = rxs
            .into_iter()
            .map(|rx| {
                let r = rx
                    .recv_timeout(Duration::from_secs(30))
                    .expect("reply despite churn")
                    .expect("request served, not shed");
                (r.class, r.logits)
            })
            .collect();
        (resps, server.shutdown())
    };
    let (reference, rm) = run(1, None);
    assert_eq!(rm.requests, N as u64);
    assert_eq!(rm.steals, 0, "one shard has nothing to steal");
    for workers in [2usize, 4] {
        for seed in [5u64, 11] {
            let (got, m) = run(
                workers,
                Some(Arc::new(FaultPlan::seeded(seed, workers))),
            );
            assert_eq!(
                got, reference,
                "seed {seed}/{workers}w: sharded door drifted from \
                 the single-batcher reference"
            );
            assert_eq!(m.requests, N as u64);
            assert_eq!(m.submitted, N as u64);
            assert_eq!(m.failed, 0);
            assert_eq!(
                m.accounted(),
                m.submitted,
                "seed {seed}/{workers}w: conservation identity"
            );
            assert_eq!(
                m.errors, 1,
                "seed {seed}/{workers}w: seeded plans kill exactly \
                 one worker"
            );
        }
    }
}

#[test]
fn saturated_shard_drains_through_sibling_steals() {
    // Two workers; worker 0's engine is gated shut, worker 1 free.
    // Submits round-robin into both shards; once worker 0 blocks
    // inside its engine, its shard can only drain through worker 1's
    // whole-batch steals. Every request must still be answered and
    // the steal counters must show the rescue happened — no
    // starvation behind a stuck sibling.
    const N: usize = 64;
    let gate = Arc::new(Mutex::new(()));
    let gate_w0 = Arc::clone(&gate);
    let factory: EngineFactory = Arc::new(move |wi: usize| {
        let inner = TagEngine {
            cap: 4,
            images: Arc::new(AtomicUsize::new(0)),
            batches: Arc::new(AtomicUsize::new(0)),
        };
        Ok(if wi == 0 {
            Box::new(GateEngine {
                inner,
                gate: Arc::clone(&gate_w0),
            }) as Box<dyn InferenceEngine>
        } else {
            Box::new(inner) as Box<dyn InferenceEngine>
        })
    });
    let server =
        InferenceServer::start_with_engines(stress_config(2), factory)
            .unwrap();
    let hold = gate.lock().unwrap();
    let rxs: Vec<_> = (0..N)
        .map(|i| server.submit(tagged_image(i)).unwrap())
        .collect();
    // Give worker 1 time to drain its own shard and steal shard 0
    // dry while worker 0 is stuck on its first batch.
    std::thread::sleep(Duration::from_millis(1500));
    drop(hold);
    for (tag, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("reply while a sibling was blocked")
            .expect("request served, not shed");
        assert_eq!(resp.class, tag % 7, "class for {tag}");
        assert_eq!(resp.logits[0], tag as f32, "echo for {tag}");
    }
    let m = server.shutdown();
    assert_eq!(m.requests, N as u64, "no request starved");
    assert_eq!(m.accounted(), m.submitted);
    assert!(
        m.steals >= 1,
        "the free sibling must steal the stuck shard"
    );
    assert!(m.stolen_requests >= 1);
    assert!(m.pulls >= 1, "own-shard pulls still happen");
}

/// One run of the full-shed-then-burst scenario; returns the batch
/// count for the post-shed burst of 4 (1 when it coalesced).
fn full_shed_then_burst_batches() -> u64 {
    let gate = Arc::new(Mutex::new(()));
    let factory = gated_factory(Arc::clone(&gate));
    let mut cfg = stress_config(1);
    cfg.policy = BatchPolicy {
        max_batch: 4,
        linger: Duration::from_millis(200),
    };
    let server =
        InferenceServer::start_with_engines(cfg, factory).unwrap();
    // Head request occupies the worker inside the gated engine; the
    // 300ms sleep outlives the linger so its batch closes alone.
    let hold = gate.lock().unwrap();
    let head = server.submit(tagged_image(0)).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    // Four doomed requests age out in the shard while the worker is
    // stuck; the next pull swings the whole batch into deadline sheds
    // (`shipped.is_empty()` in the dispatch loop).
    let doomed: Vec<_> = (1..5)
        .map(|i| {
            server
                .submit_within(
                    tagged_image(i),
                    Duration::from_millis(50),
                )
                .unwrap()
        })
        .collect();
    std::thread::sleep(Duration::from_millis(300));
    drop(hold);
    head.recv_timeout(Duration::from_secs(30))
        .expect("head answered")
        .expect("head served");
    for rx in doomed {
        let rej = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("typed answer")
            .expect_err("aged request must shed");
        assert_eq!(rej.reason.key(), "deadline-batch");
    }
    // The worker fell out of a fully-shed pull; it must be back in
    // the coalescing pull, so a back-to-back burst of 4 lands in ONE
    // policy-shaped batch under the 200ms linger.
    let rxs: Vec<_> = (0..4)
        .map(|i| server.submit(tagged_image(10 + i)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30))
            .expect("burst answered")
            .expect("burst served");
    }
    let m = server.shutdown();
    assert_eq!(m.shed_deadline_batch, 4);
    assert_eq!(m.requests, 5);
    assert_eq!(m.accounted(), m.submitted);
    // Shed-only pulls run no batch, so: head's batch + the burst's.
    m.batches - 1
}

#[test]
fn full_shed_pull_still_coalesces_next_burst() {
    // Satellite regression (ISSUE 9): a pull whose every request
    // sheds on deadline leaves nothing to ship; the worker must fall
    // straight back into the coalescing pull — not a raw recv that
    // would split the next burst into singleton batches. Bounded
    // retry absorbs CI descheduling past the linger, as in
    // `idle_arrivals_still_coalesce`.
    for attempt in 0..3 {
        if full_shed_then_burst_batches() == 1 {
            return;
        }
        eprintln!("attempt {attempt}: burst split by scheduling");
    }
    panic!("post-shed bursts never coalesced into one batch in 3 runs");
}

#[test]
fn exec_pool_job_accounting_across_worker_counts() {
    // ISSUE 6 satellite: submitted == executed after every join, for
    // helper-only (0 threads) through oversubscribed pools.
    for threads in [0usize, 1, 2, 4] {
        let pool = ExecPool::new(threads);
        pool.scope(|s| {
            for i in 0..40 {
                s.submit(move || {
                    std::hint::black_box(i * i);
                });
            }
        });
        let st = pool.stats();
        assert_eq!(
            st.jobs_submitted, 40,
            "{threads} threads: submissions miscounted"
        );
        assert_eq!(
            st.jobs_submitted, st.jobs_executed,
            "{threads} threads: jobs lost between submit and join"
        );
        assert!(st.jobs_helped <= st.jobs_executed);
        assert!(st.queue_highwater >= 1);
        if threads == 0 {
            assert_eq!(
                st.jobs_helped, 40,
                "no workers: the joiner must run every job"
            );
        }
    }
}
